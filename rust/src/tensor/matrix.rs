//! Row-major dense f32 matrix.

use crate::util::parallel::{parallel_fill_rows};
use crate::util::rng::Rng;

/// Dense row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn full(rows: usize, cols: usize, v: f32) -> Matrix {
        Matrix { rows, cols, data: vec![v; rows * cols] }
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Re-wrap a recycled buffer as a `rows × cols` matrix **without
    /// zeroing**: the buffer is resized to fit and may still hold stale
    /// values, so this is only for callers that overwrite the contents
    /// completely (the `spmm_into` kernels do). This is the allocation-free
    /// path behind the GNN engine's workspace pool.
    pub fn from_buffer(rows: usize, cols: usize, mut data: Vec<f32>) -> Matrix {
        data.resize(rows * cols, 0.0);
        Matrix { rows, cols, data }
    }

    /// Consume the matrix, returning its backing buffer (for recycling).
    pub fn into_buffer(self) -> Vec<f32> {
        self.data
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Glorot/Xavier-uniform initialization (used for GNN weights).
    pub fn glorot(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        let limit = (6.0 / (rows + cols) as f64).sqrt();
        let data = (0..rows * cols)
            .map(|_| rng.uniform(-limit, limit) as f32)
            .collect();
        Matrix { rows, cols, data }
    }

    /// Uniform random in [0,1).
    pub fn rand(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.next_f32()).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Transpose (parallel over output rows).
    pub fn transpose(&self) -> Matrix {
        let (r, c) = (self.rows, self.cols);
        let mut out = Matrix::zeros(c, r);
        let src = &self.data;
        parallel_fill_rows(&mut out.data, c, r, |range, chunk| {
            for (jj, j) in range.clone().enumerate() {
                let dst = &mut chunk[jj * r..(jj + 1) * r];
                for i in 0..r {
                    dst[i] = src[i * c + j];
                }
            }
        });
        out
    }

    /// Threaded blocked GEMM: `self (n×k) · other (k×m) → (n×m)`.
    ///
    /// Inner kernel iterates `i, l, j` so the innermost loop streams both the
    /// B row and the C row — auto-vectorizes well and is cache-friendly for
    /// row-major storage.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (n, k, m) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(n, m);
        let a = &self.data;
        let b = &other.data;
        parallel_fill_rows(&mut out.data, n, m, |range, chunk| {
            for (ii, i) in range.clone().enumerate() {
                let c_row = &mut chunk[ii * m..(ii + 1) * m];
                let a_row = &a[i * k..(i + 1) * k];
                for (l, &a_il) in a_row.iter().enumerate() {
                    if a_il == 0.0 {
                        continue;
                    }
                    let b_row = &b[l * m..(l + 1) * m];
                    for (c_v, &b_v) in c_row.iter_mut().zip(b_row.iter()) {
                        *c_v += a_il * b_v;
                    }
                }
            }
        });
        out
    }

    /// `self · otherᵀ` without materializing the transpose.
    pub fn matmul_t(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (n, k, m) = (self.rows, self.cols, other.rows);
        let mut out = Matrix::zeros(n, m);
        let a = &self.data;
        let b = &other.data;
        parallel_fill_rows(&mut out.data, n, m, |range, chunk| {
            for (ii, i) in range.clone().enumerate() {
                let a_row = &a[i * k..(i + 1) * k];
                let c_row = &mut chunk[ii * m..(ii + 1) * m];
                for j in 0..m {
                    let b_row = &b[j * k..(j + 1) * k];
                    let mut acc = 0.0f32;
                    for (&x, &y) in a_row.iter().zip(b_row.iter()) {
                        acc += x * y;
                    }
                    c_row[j] = acc;
                }
            }
        });
        out
    }

    /// `selfᵀ · other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (k, n, m) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(n, m);
        let a = &self.data;
        let b = &other.data;
        parallel_fill_rows(&mut out.data, n, m, |range, chunk| {
            for (ii, i) in range.clone().enumerate() {
                let c_row = &mut chunk[ii * m..(ii + 1) * m];
                for l in 0..k {
                    let a_li = a[l * n + i];
                    if a_li == 0.0 {
                        continue;
                    }
                    let b_row = &b[l * m..(l + 1) * m];
                    for (c_v, &b_v) in c_row.iter_mut().zip(b_row.iter()) {
                        *c_v += a_li * b_v;
                    }
                }
            }
        });
        out
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|&x| x * x).sum::<f32>().sqrt()
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0;
                for l in 0..a.cols {
                    acc += a.at(i, l) * b.at(l, j);
                }
                *out.at_mut(i, j) = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(n, k, m) in &[(1usize, 1usize, 1usize), (3, 4, 5), (17, 9, 23), (64, 64, 64)] {
            let a = Matrix::rand(n, k, &mut rng);
            let b = Matrix::rand(k, m, &mut rng);
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-4, "({n},{k},{m})");
        }
    }

    #[test]
    fn matmul_t_and_t_matmul_match() {
        let mut rng = Rng::new(2);
        let a = Matrix::rand(13, 7, &mut rng);
        let b = Matrix::rand(11, 7, &mut rng);
        let want = naive_matmul(&a, &b.transpose());
        assert!(a.matmul_t(&b).max_abs_diff(&want) < 1e-4);

        let c = Matrix::rand(7, 13, &mut rng);
        let d = Matrix::rand(7, 5, &mut rng);
        let want2 = naive_matmul(&c.transpose(), &d);
        assert!(c.t_matmul(&d).max_abs_diff(&want2) < 1e-4);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Matrix::rand(9, 17, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(5, 3), a.at(3, 5));
    }

    #[test]
    fn eye_is_identity_for_matmul() {
        let mut rng = Rng::new(4);
        let a = Matrix::rand(8, 8, &mut rng);
        let i = Matrix::eye(8);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-6);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn from_buffer_reuses_allocation() {
        let m = Matrix::full(4, 3, 7.0);
        let buf = m.into_buffer();
        let ptr = buf.as_ptr();
        // Shrinking reuse keeps the allocation (and may keep stale values).
        let m2 = Matrix::from_buffer(2, 3, buf);
        assert_eq!(m2.shape(), (2, 3));
        assert_eq!(m2.data.as_ptr(), ptr);
        // Growing reuse zero-fills the new tail.
        let m3 = Matrix::from_buffer(5, 3, m2.into_buffer());
        assert_eq!(m3.data.len(), 15);
        assert!(m3.data[6..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn glorot_within_limit() {
        let mut rng = Rng::new(5);
        let m = Matrix::glorot(50, 70, &mut rng);
        let limit = (6.0f64 / 120.0).sqrt() as f32 + 1e-6;
        assert!(m.data.iter().all(|&v| v.abs() <= limit));
        // Not all zero:
        assert!(m.norm() > 0.0);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        let _ = a.matmul(&b);
    }
}
