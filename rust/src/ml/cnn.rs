//! Convolutional matrix-image classifier — the prior-work baseline of
//! Table 3 ([45] Zhao et al., [24] Pichel et al.), which feeds a fixed-size
//! density *thumbnail* of the sparse matrix to a CNN.
//!
//! The paper used an off-the-shelf ResNet; we build a compact from-scratch
//! CNN (conv3×3 → ReLU → maxpool2 → conv3×3 → ReLU → maxpool2 → FC) which
//! faces the same core limitation the paper reports: with only ~300
//! training matrices, the image model generalizes worse than the
//! feature-based GBDT (Table 3: 66.8% vs 89.1%).

use crate::sparse::Coo;
use crate::tensor::{ops, Matrix};
use crate::util::rng::Rng;

/// Thumbnail edge length (the "matrix image" resolution).
pub const THUMB: usize = 32;

/// Render a sparse matrix as a `THUMB × THUMB` density image: each pixel is
/// the normalized non-zero count of the corresponding sub-block.
pub fn thumbnail(m: &Coo) -> Vec<f32> {
    let mut img = vec![0f32; THUMB * THUMB];
    if m.rows == 0 || m.cols == 0 || m.nnz() == 0 {
        return img;
    }
    let rs = THUMB as f64 / m.rows as f64;
    let cs = THUMB as f64 / m.cols as f64;
    for i in 0..m.nnz() {
        let pr = ((m.row[i] as f64 * rs) as usize).min(THUMB - 1);
        let pc = ((m.col[i] as f64 * cs) as usize).min(THUMB - 1);
        img[pr * THUMB + pc] += 1.0;
    }
    let max = img.iter().cloned().fold(0.0f32, f32::max).max(1e-12);
    for v in &mut img {
        *v /= max;
    }
    img
}

/// CNN hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct CnnParams {
    pub c1: usize,
    pub c2: usize,
    pub epochs: usize,
    pub batch: usize,
    pub learning_rate: f32,
    pub seed: u64,
}

impl Default for CnnParams {
    fn default() -> Self {
        CnnParams { c1: 8, c2: 16, epochs: 30, batch: 16, learning_rate: 0.005, seed: 0xC44 }
    }
}

/// Fitted CNN. Architecture (for THUMB=32):
/// conv3×3(1→c1) → ReLU → pool2 (16×16) → conv3×3(c1→c2) → ReLU → pool2
/// (8×8) → flatten (c2·64) → FC → logits.
#[derive(Clone, Debug)]
pub struct Cnn {
    k1: Vec<f32>, // [c1][1][3][3]
    b1: Vec<f32>,
    k2: Vec<f32>, // [c2][c1][3][3]
    b2: Vec<f32>,
    fc: Matrix, // (c2*8*8) × n_classes
    fcb: Vec<f32>,
    params: CnnParams,
    pub n_classes: usize,
}

const S1: usize = THUMB; // conv1 spatial (padded conv keeps size)
const P1: usize = THUMB / 2; // after pool1
const P2: usize = THUMB / 4; // after pool2

/// 3×3 same-padding convolution over a multi-channel square image.
fn conv3x3(
    input: &[f32],
    in_ch: usize,
    size: usize,
    kernels: &[f32],
    bias: &[f32],
    out_ch: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; out_ch * size * size];
    for oc in 0..out_ch {
        for y in 0..size {
            for x in 0..size {
                let mut acc = bias[oc];
                for ic in 0..in_ch {
                    let kbase = ((oc * in_ch) + ic) * 9;
                    for ky in 0..3usize {
                        let iy = y + ky;
                        if iy < 1 || iy > size {
                            continue;
                        }
                        let iy = iy - 1;
                        for kx in 0..3usize {
                            let ix = x + kx;
                            if ix < 1 || ix > size {
                                continue;
                            }
                            let ix = ix - 1;
                            acc += kernels[kbase + ky * 3 + kx]
                                * input[ic * size * size + iy * size + ix];
                        }
                    }
                }
                out[oc * size * size + y * size + x] = acc;
            }
        }
    }
    out
}

/// Gradient of `conv3x3` wrt input, kernels, bias.
#[allow(clippy::too_many_arguments)]
fn conv3x3_backward(
    input: &[f32],
    in_ch: usize,
    size: usize,
    kernels: &[f32],
    out_ch: usize,
    dout: &[f32],
    dkernels: &mut [f32],
    dbias: &mut [f32],
) -> Vec<f32> {
    let mut dinput = vec![0f32; in_ch * size * size];
    for oc in 0..out_ch {
        for y in 0..size {
            for x in 0..size {
                let g = dout[oc * size * size + y * size + x];
                if g == 0.0 {
                    continue;
                }
                dbias[oc] += g;
                for ic in 0..in_ch {
                    let kbase = ((oc * in_ch) + ic) * 9;
                    for ky in 0..3usize {
                        let iy = y + ky;
                        if iy < 1 || iy > size {
                            continue;
                        }
                        let iy = iy - 1;
                        for kx in 0..3usize {
                            let ix = x + kx;
                            if ix < 1 || ix > size {
                                continue;
                            }
                            let ix = ix - 1;
                            let idx = ic * size * size + iy * size + ix;
                            dkernels[kbase + ky * 3 + kx] += g * input[idx];
                            dinput[idx] += g * kernels[kbase + ky * 3 + kx];
                        }
                    }
                }
            }
        }
    }
    dinput
}

/// 2×2 max-pool; returns (pooled, argmax indices for backward).
fn maxpool2(input: &[f32], ch: usize, size: usize) -> (Vec<f32>, Vec<usize>) {
    let half = size / 2;
    let mut out = vec![0f32; ch * half * half];
    let mut arg = vec![0usize; ch * half * half];
    for c in 0..ch {
        for y in 0..half {
            for x in 0..half {
                let mut best = f32::NEG_INFINITY;
                let mut best_idx = 0;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let idx = c * size * size + (2 * y + dy) * size + (2 * x + dx);
                        if input[idx] > best {
                            best = input[idx];
                            best_idx = idx;
                        }
                    }
                }
                out[c * half * half + y * half + x] = best;
                arg[c * half * half + y * half + x] = best_idx;
            }
        }
    }
    (out, arg)
}

struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: usize,
}

impl Adam {
    fn new(len: usize) -> Adam {
        Adam { m: vec![0.0; len], v: vec![0.0; len], t: 0 }
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        self.t += 1;
        let bc1 = 1.0 - B1.powi(self.t as i32);
        let bc2 = 1.0 - B2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * grads[i];
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * grads[i] * grads[i];
            params[i] -= lr * (self.m[i] / bc1) / ((self.v[i] / bc2).sqrt() + 1e-8);
        }
    }
}

struct Forward {
    z1: Vec<f32>,
    a1p: Vec<f32>,
    arg1: Vec<usize>,
    z2: Vec<f32>,
    a2p: Vec<f32>,
    arg2: Vec<usize>,
    logits: Vec<f32>,
}

impl Cnn {
    /// Train on `(image, label)` pairs; images are `THUMB²` density maps.
    pub fn fit(images: &[Vec<f32>], labels: &[usize], n_classes: usize, params: CnnParams) -> Cnn {
        assert_eq!(images.len(), labels.len());
        let mut rng = Rng::new(params.seed);
        let scale1 = (2.0 / 9.0f64).sqrt();
        let scale2 = (2.0 / (9.0 * params.c1 as f64)).sqrt();
        let fc_in = params.c2 * P2 * P2;
        let mut model = Cnn {
            k1: (0..params.c1 * 9).map(|_| (rng.normal() * scale1) as f32).collect(),
            b1: vec![0.0; params.c1],
            k2: (0..params.c2 * params.c1 * 9)
                .map(|_| (rng.normal() * scale2) as f32)
                .collect(),
            b2: vec![0.0; params.c2],
            fc: Matrix::glorot(fc_in, n_classes, &mut rng),
            fcb: vec![0.0; n_classes],
            params,
            n_classes,
        };
        if images.is_empty() {
            return model;
        }
        let mut ok1 = Adam::new(model.k1.len());
        let mut ob1 = Adam::new(model.b1.len());
        let mut ok2 = Adam::new(model.k2.len());
        let mut ob2 = Adam::new(model.b2.len());
        let mut ofc = Adam::new(model.fc.data.len());
        let mut ofcb = Adam::new(model.fcb.len());

        let mut order: Vec<usize> = (0..images.len()).collect();
        for _epoch in 0..params.epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(params.batch) {
                let mut dk1 = vec![0f32; model.k1.len()];
                let mut db1 = vec![0f32; model.b1.len()];
                let mut dk2 = vec![0f32; model.k2.len()];
                let mut db2 = vec![0f32; model.b2.len()];
                let mut dfc = vec![0f32; model.fc.data.len()];
                let mut dfcb = vec![0f32; model.fcb.len()];
                for &i in chunk {
                    model.backward_one(
                        &images[i], labels[i], &mut dk1, &mut db1, &mut dk2, &mut db2,
                        &mut dfc, &mut dfcb,
                    );
                }
                let inv = 1.0 / chunk.len() as f32;
                for g in [&mut dk1, &mut db1, &mut dk2, &mut db2, &mut dfc, &mut dfcb] {
                    for v in g.iter_mut() {
                        *v *= inv;
                    }
                }
                ok1.step(&mut model.k1, &dk1, params.learning_rate);
                ob1.step(&mut model.b1, &db1, params.learning_rate);
                ok2.step(&mut model.k2, &dk2, params.learning_rate);
                ob2.step(&mut model.b2, &db2, params.learning_rate);
                ofc.step(&mut model.fc.data, &dfc, params.learning_rate);
                ofcb.step(&mut model.fcb, &dfcb, params.learning_rate);
            }
        }
        model
    }

    fn forward(&self, img: &[f32]) -> Forward {
        let c1 = self.params.c1;
        let c2 = self.params.c2;
        let z1 = conv3x3(img, 1, S1, &self.k1, &self.b1, c1);
        let a1: Vec<f32> = z1.iter().map(|&v| v.max(0.0)).collect();
        let (a1p, arg1) = maxpool2(&a1, c1, S1);
        let z2 = conv3x3(&a1p, c1, P1, &self.k2, &self.b2, c2);
        let a2: Vec<f32> = z2.iter().map(|&v| v.max(0.0)).collect();
        let (a2p, arg2) = maxpool2(&a2, c2, P1);
        // FC.
        let mut logits = self.fcb.clone();
        for (j, &v) in a2p.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            for (c, l) in logits.iter_mut().enumerate() {
                *l += v * self.fc.data[j * self.n_classes + c];
            }
        }
        Forward { z1, a1p, arg1, z2, a2p, arg2, logits }
    }

    #[allow(clippy::too_many_arguments)]
    fn backward_one(
        &self,
        img: &[f32],
        label: usize,
        dk1: &mut [f32],
        db1: &mut [f32],
        dk2: &mut [f32],
        db2: &mut [f32],
        dfc: &mut [f32],
        dfcb: &mut [f32],
    ) {
        let c1 = self.params.c1;
        let c2 = self.params.c2;
        let fwd = self.forward(img);
        // Softmax xent gradient.
        let max = fwd.logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = fwd.logits.iter().map(|&v| (v - max).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let mut dlogits: Vec<f32> = exps.iter().map(|&e| e / sum).collect();
        dlogits[label] -= 1.0;
        // FC backward.
        let mut da2p = vec![0f32; fwd.a2p.len()];
        for (j, &v) in fwd.a2p.iter().enumerate() {
            for (c, &g) in dlogits.iter().enumerate() {
                dfc[j * self.n_classes + c] += v * g;
                da2p[j] += self.fc.data[j * self.n_classes + c] * g;
            }
        }
        for (c, &g) in dlogits.iter().enumerate() {
            dfcb[c] += g;
        }
        // Unpool2 + ReLU2.
        let mut da2 = vec![0f32; c2 * P1 * P1];
        for (o, &src) in fwd.arg2.iter().enumerate() {
            da2[src] += da2p[o];
        }
        for (g, &z) in da2.iter_mut().zip(fwd.z2.iter()) {
            if z <= 0.0 {
                *g = 0.0;
            }
        }
        // Conv2 backward.
        let da1p = conv3x3_backward(&fwd.a1p, c1, P1, &self.k2, c2, &da2, dk2, db2);
        // Unpool1 + ReLU1.
        let mut da1 = vec![0f32; c1 * S1 * S1];
        for (o, &src) in fwd.arg1.iter().enumerate() {
            da1[src] += da1p[o];
        }
        for (g, &z) in da1.iter_mut().zip(fwd.z1.iter()) {
            if z <= 0.0 {
                *g = 0.0;
            }
        }
        // Conv1 backward (input gradient unused).
        let _ = conv3x3_backward(img, 1, S1, &self.k1, c1, &da1, dk1, db1);
    }

    /// Predict the class of a `THUMB²` image.
    pub fn predict_image(&self, img: &[f32]) -> usize {
        let fwd = self.forward(img);
        fwd.logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    pub fn name(&self) -> &'static str {
        "CNN"
    }
}

// Re-export ops so the unused-import lint stays quiet if ops usage changes.
#[allow(unused_imports)]
use ops as _tensor_ops;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Synthetic image classes with strong spatial structure: class 0 =
    /// top-left quadrant dense, class 1 = bottom-right dense.
    fn corner_images(rng: &mut Rng, n: usize) -> (Vec<Vec<f32>>, Vec<usize>) {
        let mut imgs = Vec::new();
        let mut labels = Vec::new();
        for _ in 0..n {
            let label = usize::from(rng.bernoulli(0.5));
            let mut img = vec![0f32; THUMB * THUMB];
            for y in 0..THUMB / 2 {
                for x in 0..THUMB / 2 {
                    let (yy, xx) = if label == 0 { (y, x) } else { (y + THUMB / 2, x + THUMB / 2) };
                    img[yy * THUMB + xx] = 0.5 + 0.5 * rng.next_f32();
                }
            }
            imgs.push(img);
            labels.push(label);
        }
        (imgs, labels)
    }

    #[test]
    fn learns_spatial_classes() {
        let mut rng = Rng::new(1);
        let (imgs, labels) = corner_images(&mut rng, 60);
        let cnn = Cnn::fit(
            &imgs,
            &labels,
            2,
            CnnParams { epochs: 8, c1: 4, c2: 8, ..Default::default() },
        );
        let (test_imgs, test_labels) = corner_images(&mut rng, 20);
        let correct = test_imgs
            .iter()
            .zip(test_labels.iter())
            .filter(|(img, &l)| cnn.predict_image(img) == l)
            .count();
        assert!(correct >= 18, "CNN should learn corners: {correct}/20");
    }

    #[test]
    fn thumbnail_normalized_and_shaped() {
        let mut rng = Rng::new(2);
        let mut triples = Vec::new();
        for r in 0..100u32 {
            for c in 0..80u32 {
                if rng.bernoulli(0.1) {
                    triples.push((r, c, 1.0f32));
                }
            }
        }
        let coo = Coo::from_triples(100, 80, triples);
        let img = thumbnail(&coo);
        assert_eq!(img.len(), THUMB * THUMB);
        let max = img.iter().cloned().fold(0.0f32, f32::max);
        assert!((max - 1.0).abs() < 1e-6);
        assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn thumbnail_empty_matrix() {
        let coo = Coo::from_triples(10, 10, vec![]);
        let img = thumbnail(&coo);
        assert!(img.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn maxpool_argmax_correct() {
        let input = vec![1.0, 2.0, 3.0, 4.0]; // 2x2 single channel
        let (out, arg) = maxpool2(&input, 1, 2);
        assert_eq!(out, vec![4.0]);
        assert_eq!(arg, vec![3]);
    }
}
