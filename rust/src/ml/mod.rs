//! From-scratch machine-learning stack (offline build — no external ML
//! crates):
//!
//! * [`gbdt`] — gradient-boosted decision trees with a softmax objective and
//!   second-order (XGBoost-style) split gain: the paper's chosen model.
//! * [`tree`] — CART classification tree: the decision-tree prior work the
//!   paper compares against (Sedaghati et al. [27]).
//! * [`knn`], [`svm`], [`mlp`] — the alternative classifiers of Fig. 11.
//! * [`cnn`] — a small convolutional network over a density thumbnail of the
//!   matrix: the matrix-as-image prior work of Table 3 ([45, 24]).
//! * [`metrics`] — accuracy, confusion matrices, k-fold cross-validation.

pub mod metrics;
pub mod tree;
pub mod gbdt;
pub mod knn;
pub mod svm;
pub mod mlp;
pub mod cnn;

/// A labeled tabular dataset (feature vectors + class labels).
#[derive(Clone, Debug, Default)]
pub struct TabularData {
    /// Row-major feature vectors, all the same arity.
    pub x: Vec<Vec<f64>>,
    /// Class label per row, in `[0, n_classes)`.
    pub y: Vec<usize>,
    pub n_classes: usize,
}

impl TabularData {
    pub fn new(x: Vec<Vec<f64>>, y: Vec<usize>, n_classes: usize) -> TabularData {
        assert_eq!(x.len(), y.len());
        assert!(y.iter().all(|&l| l < n_classes));
        TabularData { x, y, n_classes }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn n_features(&self) -> usize {
        self.x.first().map(|r| r.len()).unwrap_or(0)
    }

    /// Select a row subset.
    pub fn subset(&self, idx: &[usize]) -> TabularData {
        TabularData {
            x: idx.iter().map(|&i| self.x[i].clone()).collect(),
            y: idx.iter().map(|&i| self.y[i]).collect(),
            n_classes: self.n_classes,
        }
    }
}

/// Common interface for the Fig-11 / Table-3 model comparison.
pub trait Classifier {
    /// Predict the class label of one feature vector.
    fn predict(&self, x: &[f64]) -> usize;

    /// Model name for reports.
    fn name(&self) -> &'static str;

    fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<usize> {
        xs.iter().map(|x| self.predict(x)).collect()
    }
}

#[cfg(test)]
pub(crate) mod testdata {
    use super::TabularData;
    use crate::util::rng::Rng;

    /// Gaussian blobs: `n_classes` well-separated clusters in `dim`-D.
    pub fn blobs(rng: &mut Rng, n_per_class: usize, n_classes: usize, dim: usize) -> TabularData {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for k in 0..n_classes {
            let center: Vec<f64> = (0..dim).map(|j| ((k * dim + j) % 7) as f64 * 2.0).collect();
            for _ in 0..n_per_class {
                x.push(center.iter().map(|&c| c + rng.normal() * 0.3).collect());
                y.push(k);
            }
        }
        TabularData::new(x, y, n_classes)
    }

    /// XOR: not linearly separable — trees/MLP should solve it, linear SVM
    /// should not.
    pub fn xor(rng: &mut Rng, n: usize) -> TabularData {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.bernoulli(0.5);
            let b = rng.bernoulli(0.5);
            x.push(vec![
                f64::from(a) + rng.normal() * 0.1,
                f64::from(b) + rng.normal() * 0.1,
            ]);
            y.push(usize::from(a ^ b));
        }
        TabularData::new(x, y, 2)
    }
}
