//! Linear SVM, one-vs-rest, trained with hinge-loss SGD [22] — one of the
//! paper's Fig-11 comparison models.

use super::{Classifier, TabularData};
use crate::util::rng::Rng;

/// SVM hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct SvmParams {
    pub epochs: usize,
    pub learning_rate: f64,
    /// L2 regularization strength.
    pub reg: f64,
    pub seed: u64,
}

impl Default for SvmParams {
    fn default() -> Self {
        SvmParams { epochs: 60, learning_rate: 0.05, reg: 1e-4, seed: 0x5EED }
    }
}

/// One-vs-rest linear SVM.
#[derive(Clone, Debug)]
pub struct Svm {
    /// `weights[class]` has `n_features + 1` entries (last = bias).
    weights: Vec<Vec<f64>>,
    pub n_classes: usize,
}

impl Svm {
    pub fn fit(data: &TabularData, params: SvmParams) -> Svm {
        let nf = data.n_features();
        let mut weights = vec![vec![0.0; nf + 1]; data.n_classes];
        let mut rng = Rng::new(params.seed);
        let mut order: Vec<usize> = (0..data.len()).collect();
        for epoch in 0..params.epochs {
            rng.shuffle(&mut order);
            let lr = params.learning_rate / (1.0 + epoch as f64 * 0.1);
            for &i in &order {
                let x = &data.x[i];
                for (class, w) in weights.iter_mut().enumerate() {
                    let y = if data.y[i] == class { 1.0 } else { -1.0 };
                    let margin = y * (dot(w, x) + w[nf]);
                    // L2 shrink.
                    for wj in w.iter_mut().take(nf) {
                        *wj *= 1.0 - lr * params.reg;
                    }
                    if margin < 1.0 {
                        for j in 0..nf {
                            w[j] += lr * y * x[j];
                        }
                        w[nf] += lr * y;
                    }
                }
            }
        }
        Svm { weights, n_classes: data.n_classes }
    }

    fn score(&self, class: usize, x: &[f64]) -> f64 {
        let w = &self.weights[class];
        dot(w, x) + w[w.len() - 1]
    }
}

fn dot(w: &[f64], x: &[f64]) -> f64 {
    w.iter().zip(x.iter()).map(|(&a, &b)| a * b).sum()
}

impl Classifier for Svm {
    fn predict(&self, x: &[f64]) -> usize {
        (0..self.n_classes)
            .max_by(|&a, &b| self.score(a, x).partial_cmp(&self.score(b, x)).unwrap())
            .unwrap_or(0)
    }

    fn name(&self) -> &'static str {
        "SVM"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;
    use crate::ml::testdata;
    use crate::util::rng::Rng;

    #[test]
    fn separates_blobs() {
        let mut rng = Rng::new(1);
        let data = testdata::blobs(&mut rng, 40, 3, 4);
        let svm = Svm::fit(&data, SvmParams::default());
        let pred = svm.predict_batch(&data.x);
        assert!(accuracy(&pred, &data.y) > 0.95);
    }

    #[test]
    fn linear_model_fails_xor() {
        let mut rng = Rng::new(2);
        let data = testdata::xor(&mut rng, 400);
        let svm = Svm::fit(&data, SvmParams::default());
        let pred = svm.predict_batch(&data.x);
        let acc = accuracy(&pred, &data.y);
        assert!(acc < 0.8, "linear SVM should NOT solve XOR (acc={acc})");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(3);
        let data = testdata::blobs(&mut rng, 20, 2, 3);
        let a = Svm::fit(&data, SvmParams::default());
        let b = Svm::fit(&data, SvmParams::default());
        assert_eq!(a.predict_batch(&data.x), b.predict_batch(&data.x));
    }
}
