//! K-nearest-neighbour classifier (paper Fig. 11 uses k = 1 [42]).

use super::{Classifier, TabularData};

/// Fitted (memorized) KNN model.
#[derive(Clone, Debug)]
pub struct Knn {
    data: TabularData,
    pub k: usize,
}

impl Knn {
    pub fn fit(data: &TabularData, k: usize) -> Knn {
        assert!(k >= 1);
        Knn { data: data.clone(), k }
    }
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b.iter()).map(|(&x, &y)| (x - y) * (x - y)).sum()
}

impl Classifier for Knn {
    fn predict(&self, x: &[f64]) -> usize {
        if self.data.is_empty() {
            return 0;
        }
        // Partial selection of the k nearest.
        let mut dists: Vec<(f64, usize)> = self
            .data
            .x
            .iter()
            .zip(self.data.y.iter())
            .map(|(xi, &yi)| (sq_dist(x, xi), yi))
            .collect();
        let k = self.k.min(dists.len());
        dists.select_nth_unstable_by(k - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut votes = vec![0usize; self.data.n_classes];
        for &(_, y) in &dists[..k] {
            votes[y] += 1;
        }
        votes
            .iter()
            .enumerate()
            .max_by_key(|&(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    fn name(&self) -> &'static str {
        "KNN"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;
    use crate::ml::testdata;
    use crate::util::rng::Rng;

    #[test]
    fn memorizes_training_set_with_k1() {
        let mut rng = Rng::new(1);
        let data = testdata::blobs(&mut rng, 25, 3, 4);
        let knn = Knn::fit(&data, 1);
        let pred = knn.predict_batch(&data.x);
        assert_eq!(accuracy(&pred, &data.y), 1.0);
    }

    #[test]
    fn generalizes_on_blobs() {
        let mut rng = Rng::new(2);
        let train = testdata::blobs(&mut rng, 30, 3, 4);
        let test = testdata::blobs(&mut rng, 10, 3, 4);
        let knn = Knn::fit(&train, 3);
        let pred = knn.predict_batch(&test.x);
        assert!(accuracy(&pred, &test.y) > 0.95);
    }

    #[test]
    fn k_larger_than_dataset_is_safe() {
        let data = TabularData::new(vec![vec![0.0], vec![1.0]], vec![0, 1], 2);
        let knn = Knn::fit(&data, 10);
        let _ = knn.predict(&[0.4]);
    }
}
