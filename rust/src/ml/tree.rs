//! CART classification tree — exact greedy splits on Gini impurity.
//!
//! Serves two roles: the standalone decision-tree baseline of Table 3
//! (Sedaghati et al. [27]), and a reference point for the boosted ensemble
//! in [`super::gbdt`].

use super::{Classifier, TabularData};

/// Tree hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_split: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 8, min_samples_split: 4 }
    }
}

#[derive(Clone, Debug)]
pub enum Node {
    Leaf {
        /// Majority class of the samples at this leaf.
        class: usize,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// A fitted CART decision tree.
#[derive(Clone, Debug)]
pub struct DecisionTree {
    pub nodes: Vec<Node>,
    pub n_classes: usize,
}

fn gini(counts: &[usize], total: usize) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts
        .iter()
        .map(|&c| {
            let p = c as f64 / t;
            p * p
        })
        .sum::<f64>()
}

impl DecisionTree {
    /// Fit on a dataset.
    pub fn fit(data: &TabularData, params: TreeParams) -> DecisionTree {
        let mut tree = DecisionTree { nodes: Vec::new(), n_classes: data.n_classes };
        let idx: Vec<usize> = (0..data.len()).collect();
        tree.build(data, idx, 0, params);
        tree
    }

    fn majority(&self, data: &TabularData, idx: &[usize]) -> usize {
        let mut counts = vec![0usize; self.n_classes];
        for &i in idx {
            counts[data.y[i]] += 1;
        }
        counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, &c)| c)
            .map(|(k, _)| k)
            .unwrap_or(0)
    }

    /// Recursively build; returns the node id.
    fn build(
        &mut self,
        data: &TabularData,
        idx: Vec<usize>,
        depth: usize,
        params: TreeParams,
    ) -> usize {
        let node_id = self.nodes.len();
        let class = self.majority(data, &idx);
        self.nodes.push(Node::Leaf { class });

        if depth >= params.max_depth || idx.len() < params.min_samples_split {
            return node_id;
        }
        // Pure node?
        if idx.iter().all(|&i| data.y[i] == data.y[idx[0]]) {
            return node_id;
        }

        let Some((feature, threshold)) = self.best_split(data, &idx) else {
            return node_id;
        };

        let (left_idx, right_idx): (Vec<usize>, Vec<usize>) =
            idx.iter().partition(|&&i| data.x[i][feature] <= threshold);
        if left_idx.is_empty() || right_idx.is_empty() {
            return node_id;
        }
        let left = self.build(data, left_idx, depth + 1, params);
        let right = self.build(data, right_idx, depth + 1, params);
        self.nodes[node_id] = Node::Split { feature, threshold, left, right };
        node_id
    }

    /// Exact greedy: scan every feature, sorting samples and sweeping all
    /// mid-point thresholds; pick the split with the lowest weighted Gini.
    fn best_split(&self, data: &TabularData, idx: &[usize]) -> Option<(usize, f64)> {
        let n = idx.len();
        let total_counts = {
            let mut c = vec![0usize; self.n_classes];
            for &i in idx {
                c[data.y[i]] += 1;
            }
            c
        };
        let parent_gini = gini(&total_counts, n);
        let mut best: Option<(f64, usize, f64)> = None; // (impurity, feature, threshold)

        for f in 0..data.n_features() {
            let mut order: Vec<usize> = idx.to_vec();
            order.sort_by(|&a, &b| data.x[a][f].partial_cmp(&data.x[b][f]).unwrap());
            let mut left_counts = vec![0usize; self.n_classes];
            let mut right_counts = total_counts.clone();
            for pos in 0..n - 1 {
                let i = order[pos];
                left_counts[data.y[i]] += 1;
                right_counts[data.y[i]] -= 1;
                let v = data.x[i][f];
                let v_next = data.x[order[pos + 1]][f];
                if v == v_next {
                    continue; // can't split between equal values
                }
                let nl = pos + 1;
                let nr = n - nl;
                let w = (nl as f64 * gini(&left_counts, nl)
                    + nr as f64 * gini(&right_counts, nr))
                    / n as f64;
                if best.map(|(b, _, _)| w < b).unwrap_or(w < parent_gini) {
                    best = Some((w, f, (v + v_next) / 2.0));
                }
            }
        }
        best.map(|(_, f, t)| (f, t))
    }

    pub fn depth(&self) -> usize {
        fn walk(nodes: &[Node], id: usize) -> usize {
            match nodes[id] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + walk(nodes, left).max(walk(nodes, right)),
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            walk(&self.nodes, 0)
        }
    }
}

impl Classifier for DecisionTree {
    fn predict(&self, x: &[f64]) -> usize {
        let mut id = 0;
        loop {
            match self.nodes[id] {
                Node::Leaf { class } => return class,
                Node::Split { feature, threshold, left, right } => {
                    id = if x[feature] <= threshold { left } else { right };
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "DecisionTree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;
    use crate::ml::testdata;
    use crate::util::rng::Rng;

    #[test]
    fn fits_blobs_perfectly() {
        let mut rng = Rng::new(1);
        let data = testdata::blobs(&mut rng, 40, 4, 5);
        let tree = DecisionTree::fit(&data, TreeParams::default());
        let pred = tree.predict_batch(&data.x);
        assert!(accuracy(&pred, &data.y) > 0.98);
    }

    #[test]
    fn solves_xor() {
        let mut rng = Rng::new(2);
        let data = testdata::xor(&mut rng, 400);
        let tree = DecisionTree::fit(&data, TreeParams::default());
        let pred = tree.predict_batch(&data.x);
        assert!(accuracy(&pred, &data.y) > 0.95, "tree should carve XOR");
    }

    #[test]
    fn depth_limit_respected() {
        let mut rng = Rng::new(3);
        let data = testdata::blobs(&mut rng, 50, 3, 4);
        let tree = DecisionTree::fit(&data, TreeParams { max_depth: 2, min_samples_split: 2 });
        assert!(tree.depth() <= 2);
    }

    #[test]
    fn single_class_is_single_leaf() {
        let data = TabularData::new(vec![vec![1.0], vec![2.0], vec![3.0]], vec![0, 0, 0], 1);
        let tree = DecisionTree::fit(&data, TreeParams::default());
        assert_eq!(tree.nodes.len(), 1);
        assert_eq!(tree.predict(&[5.0]), 0);
    }

    #[test]
    fn generalizes_to_held_out_blobs() {
        let mut rng = Rng::new(4);
        let train = testdata::blobs(&mut rng, 50, 3, 6);
        let test = testdata::blobs(&mut rng, 20, 3, 6);
        let tree = DecisionTree::fit(&train, TreeParams::default());
        let pred = tree.predict_batch(&test.x);
        assert!(accuracy(&pred, &test.y) > 0.9);
    }
}
