//! Multilayer perceptron [12] — one hidden ReLU layer trained with
//! mini-batch Adam on softmax cross-entropy. One of the Fig-11 baselines.

use super::{Classifier, TabularData};
use crate::tensor::{ops, Matrix};
use crate::util::rng::Rng;

/// MLP hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct MlpParams {
    pub hidden: usize,
    pub epochs: usize,
    pub batch: usize,
    pub learning_rate: f32,
    pub seed: u64,
}

impl Default for MlpParams {
    fn default() -> Self {
        MlpParams { hidden: 64, epochs: 120, batch: 32, learning_rate: 0.01, seed: 0x31A9 }
    }
}

/// Fitted MLP.
#[derive(Clone, Debug)]
pub struct Mlp {
    w1: Matrix,
    b1: Vec<f32>,
    w2: Matrix,
    b2: Vec<f32>,
    pub n_classes: usize,
}

struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: usize,
}

impl Adam {
    fn new(len: usize) -> Adam {
        Adam { m: vec![0.0; len], v: vec![0.0; len], t: 0 }
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        self.t += 1;
        let bc1 = 1.0 - B1.powi(self.t as i32);
        let bc2 = 1.0 - B2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * grads[i];
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * grads[i] * grads[i];
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            params[i] -= lr * mh / (vh.sqrt() + EPS);
        }
    }
}

impl Mlp {
    pub fn fit(data: &TabularData, params: MlpParams) -> Mlp {
        let nf = data.n_features();
        let k = data.n_classes;
        let mut rng = Rng::new(params.seed);
        let mut model = Mlp {
            w1: Matrix::glorot(nf, params.hidden, &mut rng),
            b1: vec![0.0; params.hidden],
            w2: Matrix::glorot(params.hidden, k, &mut rng),
            b2: vec![0.0; k],
            n_classes: k,
        };
        if data.is_empty() {
            return model;
        }
        let mut opt_w1 = Adam::new(model.w1.data.len());
        let mut opt_b1 = Adam::new(model.b1.len());
        let mut opt_w2 = Adam::new(model.w2.data.len());
        let mut opt_b2 = Adam::new(model.b2.len());

        let mut order: Vec<usize> = (0..data.len()).collect();
        for _epoch in 0..params.epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(params.batch) {
                let bsz = chunk.len();
                let mut x = Matrix::zeros(bsz, nf);
                let mut labels = Vec::with_capacity(bsz);
                for (r, &i) in chunk.iter().enumerate() {
                    for (c, &v) in data.x[i].iter().enumerate() {
                        *x.at_mut(r, c) = v as f32;
                    }
                    labels.push(data.y[i]);
                }
                // Forward.
                let z1 = ops::add_row(&x.matmul(&model.w1), &model.b1);
                let h = ops::relu(&z1);
                let logits = ops::add_row(&h.matmul(&model.w2), &model.b2);
                let mask = vec![true; bsz];
                let (_loss, dlogits) = ops::masked_xent_with_grad(&logits, &labels, &mask);
                // Backward.
                let dw2 = h.t_matmul(&dlogits);
                let db2: Vec<f32> = (0..k)
                    .map(|c| (0..bsz).map(|r| dlogits.at(r, c)).sum())
                    .collect();
                let dh = dlogits.matmul_t(&model.w2);
                let dz1 = ops::relu_grad(&z1, &dh);
                let dw1 = x.t_matmul(&dz1);
                let db1: Vec<f32> = (0..params.hidden)
                    .map(|c| (0..bsz).map(|r| dz1.at(r, c)).sum())
                    .collect();
                // Update.
                opt_w1.step(&mut model.w1.data, &dw1.data, params.learning_rate);
                opt_b1.step(&mut model.b1, &db1, params.learning_rate);
                opt_w2.step(&mut model.w2.data, &dw2.data, params.learning_rate);
                opt_b2.step(&mut model.b2, &db2, params.learning_rate);
            }
        }
        model
    }

    fn forward_one(&self, x: &[f64]) -> Vec<f32> {
        let nf = self.w1.rows;
        let mut input = Matrix::zeros(1, nf);
        for (c, &v) in x.iter().enumerate() {
            input.data[c] = v as f32;
        }
        let h = ops::relu(&ops::add_row(&input.matmul(&self.w1), &self.b1));
        let logits = ops::add_row(&h.matmul(&self.w2), &self.b2);
        logits.data
    }
}

impl Classifier for Mlp {
    fn predict(&self, x: &[f64]) -> usize {
        let scores = self.forward_one(x);
        scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    fn name(&self) -> &'static str {
        "MLP"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;
    use crate::ml::testdata;
    use crate::util::rng::Rng;

    #[test]
    fn fits_blobs() {
        let mut rng = Rng::new(1);
        let data = testdata::blobs(&mut rng, 30, 3, 4);
        let mlp = Mlp::fit(&data, MlpParams { epochs: 60, ..Default::default() });
        let pred = mlp.predict_batch(&data.x);
        assert!(accuracy(&pred, &data.y) > 0.95);
    }

    #[test]
    fn solves_xor_unlike_linear_svm() {
        let mut rng = Rng::new(2);
        let data = testdata::xor(&mut rng, 400);
        let mlp = Mlp::fit(&data, MlpParams { epochs: 150, hidden: 32, ..Default::default() });
        let pred = mlp.predict_batch(&data.x);
        assert!(accuracy(&pred, &data.y) > 0.9);
    }
}
