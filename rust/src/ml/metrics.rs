//! Evaluation metrics and cross-validation splits.

use crate::util::rng::Rng;

/// Fraction of matching labels.
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let correct = pred.iter().zip(truth.iter()).filter(|(p, t)| p == t).count();
    correct as f64 / pred.len() as f64
}

/// `n_classes × n_classes` confusion matrix; rows = truth, cols = predicted.
pub fn confusion(pred: &[usize], truth: &[usize], n_classes: usize) -> Vec<Vec<usize>> {
    let mut m = vec![vec![0usize; n_classes]; n_classes];
    for (&p, &t) in pred.iter().zip(truth.iter()) {
        m[t][p] += 1;
    }
    m
}

/// Shuffled k-fold split: returns `(train_idx, test_idx)` per fold.
pub fn kfold(n: usize, k: usize, rng: &mut Rng) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2 && k <= n);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let folds = crate::util::parallel::split_ranges(n, k);
    folds
        .into_iter()
        .map(|r| {
            let test: Vec<usize> = idx[r.clone()].to_vec();
            let train: Vec<usize> = idx[..r.start].iter().chain(idx[r.end..].iter()).copied().collect();
            (train, test)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn confusion_counts() {
        let m = confusion(&[0, 1, 1], &[0, 0, 1], 2);
        assert_eq!(m[0][0], 1);
        assert_eq!(m[0][1], 1);
        assert_eq!(m[1][1], 1);
        assert_eq!(m[1][0], 0);
    }

    #[test]
    fn kfold_partitions() {
        let mut rng = Rng::new(1);
        let folds = kfold(100, 5, &mut rng);
        assert_eq!(folds.len(), 5);
        let mut all_test: Vec<usize> = folds.iter().flat_map(|(_, t)| t.clone()).collect();
        all_test.sort_unstable();
        assert_eq!(all_test, (0..100).collect::<Vec<_>>());
        for (train, test) in &folds {
            assert_eq!(train.len() + test.len(), 100);
            assert!(train.iter().all(|i| !test.contains(i)));
        }
    }
}
