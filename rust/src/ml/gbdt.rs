//! Gradient-boosted decision trees with softmax objective and second-order
//! split gain — a from-scratch XGBoost[7] equivalent (the paper's model).
//!
//! Per boosting round, one regression tree is fitted per class on the
//! softmax gradients/hessians; split quality uses the XGBoost structure
//! score `½·[G_L²/(H_L+λ) + G_R²/(H_R+λ) − G²/(H+λ)] − γ`, leaves output
//! `−G/(H+λ)` scaled by the learning rate.
//!
//! Gain-based feature importance (used for the paper's Fig. 7 and the
//! feature-selection step of §4.4) falls out of training for free.

use super::{Classifier, TabularData};
use crate::util::json::Json;
use crate::util::parallel::parallel_map;

/// GBDT hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct GbdtParams {
    pub n_rounds: usize,
    pub max_depth: usize,
    pub learning_rate: f64,
    /// L2 regularization on leaf weights (XGBoost λ).
    pub lambda: f64,
    /// Minimum split gain (XGBoost γ).
    pub gamma: f64,
    pub min_child_weight: f64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams {
            n_rounds: 60,
            max_depth: 4,
            learning_rate: 0.3,
            lambda: 1.0,
            gamma: 0.0,
            min_child_weight: 1.0,
        }
    }
}

/// Flat regression-tree node.
#[derive(Clone, Debug)]
enum RNode {
    Leaf { weight: f64 },
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

#[derive(Clone, Debug)]
struct RTree {
    nodes: Vec<RNode>,
}

impl RTree {
    fn predict(&self, x: &[f64]) -> f64 {
        let mut id = 0;
        loop {
            match self.nodes[id] {
                RNode::Leaf { weight } => return weight,
                RNode::Split { feature, threshold, left, right } => {
                    id = if x[feature] <= threshold { left } else { right };
                }
            }
        }
    }
}

/// A fitted gradient-boosted model.
#[derive(Clone, Debug)]
pub struct Gbdt {
    /// `trees[round][class]`.
    trees: Vec<Vec<RTree>>,
    pub n_classes: usize,
    pub n_features: usize,
    pub params: GbdtParams,
    /// Total split gain accumulated per feature during training.
    pub feature_gain: Vec<f64>,
    /// Number of splits per feature.
    pub feature_splits: Vec<usize>,
}

struct SplitCtx<'a> {
    data: &'a TabularData,
    grad: &'a [f64],
    hess: &'a [f64],
    params: GbdtParams,
}

impl Gbdt {
    /// Train with softmax cross-entropy boosting.
    pub fn fit(data: &TabularData, params: GbdtParams) -> Gbdt {
        let n = data.len();
        let k = data.n_classes;
        let mut model = Gbdt {
            trees: Vec::with_capacity(params.n_rounds),
            n_classes: k,
            n_features: data.n_features(),
            params,
            feature_gain: vec![0.0; data.n_features()],
            feature_splits: vec![0; data.n_features()],
        };
        if n == 0 || k == 0 {
            return model;
        }
        // Raw scores F[i][k].
        let mut scores = vec![0.0f64; n * k];
        for _round in 0..params.n_rounds {
            // Softmax probabilities -> per-class grad/hess.
            let mut probs = vec![0.0f64; n * k];
            for i in 0..n {
                let row = &scores[i * k..(i + 1) * k];
                let max = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mut sum = 0.0;
                for (j, &s) in row.iter().enumerate() {
                    let e = (s - max).exp();
                    probs[i * k + j] = e;
                    sum += e;
                }
                for j in 0..k {
                    probs[i * k + j] /= sum;
                }
            }
            // One tree per class, trained in parallel (they're independent).
            let class_trees: Vec<(RTree, Vec<(usize, f64)>)> = parallel_map(k, |class| {
                let mut grad = vec![0.0f64; n];
                let mut hess = vec![0.0f64; n];
                for i in 0..n {
                    let p = probs[i * k + class];
                    let y = f64::from(data.y[i] == class);
                    grad[i] = p - y;
                    hess[i] = (p * (1.0 - p)).max(1e-6);
                }
                let ctx = SplitCtx { data, grad: &grad, hess: &hess, params };
                let mut tree = RTree { nodes: Vec::new() };
                let mut gains: Vec<(usize, f64)> = Vec::new();
                let idx: Vec<usize> = (0..n).collect();
                build_rtree(&ctx, &mut tree, &mut gains, idx, 0);
                (tree, gains)
            });
            let mut round_trees = Vec::with_capacity(k);
            for (class, (tree, gains)) in class_trees.into_iter().enumerate() {
                // Update scores with shrinkage.
                for i in 0..n {
                    scores[i * k + class] += params.learning_rate * tree.predict(&data.x[i]);
                }
                for (f, g) in gains {
                    model.feature_gain[f] += g;
                    model.feature_splits[f] += 1;
                }
                round_trees.push(tree);
            }
            model.trees.push(round_trees);
        }
        model
    }

    /// Raw per-class scores for one sample.
    pub fn decision_scores(&self, x: &[f64]) -> Vec<f64> {
        let mut s = vec![0.0f64; self.n_classes];
        for round in &self.trees {
            for (class, tree) in round.iter().enumerate() {
                s[class] += self.params.learning_rate * tree.predict(x);
            }
        }
        s
    }

    /// Argmax class plus a **calibrated confidence margin**: softmax the
    /// decision scores and return `p(top1) − p(top2)` ∈ [0, 1]. The margin
    /// is what the engine's decision cache uses to decline pinning
    /// near-boundary predictions (`predictor::cache`). Ties break exactly
    /// like [`Classifier::predict`]; a single-class model reports 1.0.
    pub fn predict_with_margin(&self, x: &[f64]) -> (usize, f64) {
        let s = self.decision_scores(x);
        let max = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = s.iter().map(|v| (v - max).exp()).collect();
        let z: f64 = exps.iter().sum::<f64>().max(1e-300);
        let best = exps
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        let p1 = exps.get(best).copied().unwrap_or(1.0) / z;
        let p2 = exps
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != best)
            .map(|(_, &e)| e / z)
            .fold(0.0, f64::max);
        (best, (p1 - p2).clamp(0.0, 1.0))
    }

    /// Gain-normalized feature importance (sums to 1 unless all-zero).
    pub fn importance(&self) -> Vec<f64> {
        let total: f64 = self.feature_gain.iter().sum();
        if total <= 0.0 {
            return vec![0.0; self.n_features];
        }
        self.feature_gain.iter().map(|&g| g / total).collect()
    }

    /// Serialize the fitted ensemble to JSON.
    pub fn to_json(&self) -> Json {
        let trees = Json::arr(self.trees.iter().map(|round| {
            Json::arr(round.iter().map(|t| {
                Json::arr(t.nodes.iter().map(|n| match n {
                    RNode::Leaf { weight } => Json::obj(vec![("w", Json::Num(*weight))]),
                    RNode::Split { feature, threshold, left, right } => Json::obj(vec![
                        ("f", Json::Num(*feature as f64)),
                        ("t", Json::Num(*threshold)),
                        ("l", Json::Num(*left as f64)),
                        ("r", Json::Num(*right as f64)),
                    ]),
                }))
            }))
        }));
        Json::obj(vec![
            ("n_classes", Json::Num(self.n_classes as f64)),
            ("n_features", Json::Num(self.n_features as f64)),
            ("learning_rate", Json::Num(self.params.learning_rate)),
            ("feature_gain", Json::num_arr(self.feature_gain.iter())),
            ("trees", trees),
        ])
    }

    /// Load a serialized ensemble.
    pub fn from_json(j: &Json) -> anyhow::Result<Gbdt> {
        let n_classes = j.req_f64("n_classes")? as usize;
        let n_features = j.req_f64("n_features")? as usize;
        let lr = j.req_f64("learning_rate")?;
        let feature_gain: Vec<f64> = j
            .req_arr("feature_gain")?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0))
            .collect();
        let mut trees = Vec::new();
        for round in j.req_arr("trees")? {
            let mut rt = Vec::new();
            for t in round.as_arr().ok_or_else(|| anyhow::anyhow!("bad tree round"))? {
                let mut nodes = Vec::new();
                for nj in t.as_arr().ok_or_else(|| anyhow::anyhow!("bad tree"))? {
                    if let Some(w) = nj.get("w") {
                        nodes.push(RNode::Leaf { weight: w.as_f64().unwrap_or(0.0) });
                    } else {
                        nodes.push(RNode::Split {
                            feature: nj.req_f64("f")? as usize,
                            threshold: nj.req_f64("t")?,
                            left: nj.req_f64("l")? as usize,
                            right: nj.req_f64("r")? as usize,
                        });
                    }
                }
                rt.push(RTree { nodes });
            }
            trees.push(rt);
        }
        let params = GbdtParams { learning_rate: lr, ..GbdtParams::default() };
        Ok(Gbdt {
            trees,
            n_classes,
            n_features,
            params,
            feature_gain,
            feature_splits: vec![0; n_features],
        })
    }
}

/// Recursive second-order tree construction. Returns node id.
fn build_rtree(
    ctx: &SplitCtx,
    tree: &mut RTree,
    gains: &mut Vec<(usize, f64)>,
    idx: Vec<usize>,
    depth: usize,
) -> usize {
    let g_sum: f64 = idx.iter().map(|&i| ctx.grad[i]).sum();
    let h_sum: f64 = idx.iter().map(|&i| ctx.hess[i]).sum();
    let node_id = tree.nodes.len();
    tree.nodes.push(RNode::Leaf { weight: -g_sum / (h_sum + ctx.params.lambda) });

    if depth >= ctx.params.max_depth || idx.len() < 2 {
        return node_id;
    }

    // Exact greedy split search with prefix-sum sweep per feature.
    let parent_score = g_sum * g_sum / (h_sum + ctx.params.lambda);
    let mut best: Option<(f64, usize, f64)> = None;
    for f in 0..ctx.data.n_features() {
        let mut order = idx.clone();
        order.sort_by(|&a, &b| ctx.data.x[a][f].partial_cmp(&ctx.data.x[b][f]).unwrap());
        let mut gl = 0.0;
        let mut hl = 0.0;
        for pos in 0..order.len() - 1 {
            let i = order[pos];
            gl += ctx.grad[i];
            hl += ctx.hess[i];
            let v = ctx.data.x[i][f];
            let v_next = ctx.data.x[order[pos + 1]][f];
            if v == v_next {
                continue;
            }
            let gr = g_sum - gl;
            let hr = h_sum - hl;
            if hl < ctx.params.min_child_weight || hr < ctx.params.min_child_weight {
                continue;
            }
            let gain = 0.5
                * (gl * gl / (hl + ctx.params.lambda) + gr * gr / (hr + ctx.params.lambda)
                    - parent_score)
                - ctx.params.gamma;
            if gain > best.map(|(g, _, _)| g).unwrap_or(1e-12) {
                best = Some((gain, f, (v + v_next) / 2.0));
            }
        }
    }

    let Some((gain, feature, threshold)) = best else {
        return node_id;
    };
    let (left_idx, right_idx): (Vec<usize>, Vec<usize>) =
        idx.iter().partition(|&&i| ctx.data.x[i][feature] <= threshold);
    if left_idx.is_empty() || right_idx.is_empty() {
        return node_id;
    }
    gains.push((feature, gain));
    let left = build_rtree(ctx, tree, gains, left_idx, depth + 1);
    let right = build_rtree(ctx, tree, gains, right_idx, depth + 1);
    tree.nodes[node_id] = RNode::Split { feature, threshold, left, right };
    node_id
}

impl Classifier for Gbdt {
    fn predict(&self, x: &[f64]) -> usize {
        let s = self.decision_scores(x);
        s.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    fn name(&self) -> &'static str {
        "XGBoost"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;
    use crate::ml::testdata;
    use crate::util::rng::Rng;

    #[test]
    fn fits_blobs() {
        let mut rng = Rng::new(1);
        let data = testdata::blobs(&mut rng, 30, 4, 5);
        let model = Gbdt::fit(&data, GbdtParams { n_rounds: 20, ..Default::default() });
        let pred = model.predict_batch(&data.x);
        assert!(accuracy(&pred, &data.y) > 0.98);
    }

    #[test]
    fn solves_xor() {
        let mut rng = Rng::new(2);
        let data = testdata::xor(&mut rng, 300);
        let model = Gbdt::fit(&data, GbdtParams { n_rounds: 30, ..Default::default() });
        let pred = model.predict_batch(&data.x);
        assert!(accuracy(&pred, &data.y) > 0.95);
    }

    #[test]
    fn generalizes() {
        let mut rng = Rng::new(3);
        let train = testdata::blobs(&mut rng, 40, 3, 6);
        let test = testdata::blobs(&mut rng, 15, 3, 6);
        let model = Gbdt::fit(&train, GbdtParams { n_rounds: 25, ..Default::default() });
        let pred = model.predict_batch(&test.x);
        assert!(accuracy(&pred, &test.y) > 0.9);
    }

    #[test]
    fn importance_sums_to_one_and_finds_signal() {
        let mut rng = Rng::new(4);
        // Only feature 0 is informative.
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..200 {
            let label = rng.bernoulli(0.5);
            x.push(vec![
                f64::from(label) * 4.0 + rng.normal() * 0.2,
                rng.normal(), // noise
                rng.normal(), // noise
            ]);
            y.push(usize::from(label));
        }
        let data = TabularData::new(x, y, 2);
        let model = Gbdt::fit(&data, GbdtParams { n_rounds: 10, ..Default::default() });
        let imp = model.importance();
        assert!((imp.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(imp[0] > 0.8, "feature 0 should dominate: {imp:?}");
    }

    /// The margin is a probability gap: in [0, 1], argmax-consistent with
    /// `predict`, and high on the well-separated blobs the model fits.
    #[test]
    fn predict_with_margin_is_calibrated_and_consistent() {
        let mut rng = Rng::new(9);
        let data = testdata::blobs(&mut rng, 30, 4, 5);
        let model = Gbdt::fit(&data, GbdtParams { n_rounds: 20, ..Default::default() });
        let mut confident = 0usize;
        for x in &data.x {
            let (label, margin) = model.predict_with_margin(x);
            assert_eq!(label, model.predict(x), "argmax must match predict");
            assert!((0.0..=1.0).contains(&margin), "margin {margin} out of range");
            if margin > 0.5 {
                confident += 1;
            }
        }
        // Well-separated blobs: the fitted model should be confidently
        // right on most of its own training points.
        assert!(confident * 2 > data.x.len(), "{confident}/{} confident", data.x.len());
    }

    #[test]
    fn json_roundtrip_preserves_predictions() {
        let mut rng = Rng::new(5);
        let data = testdata::blobs(&mut rng, 20, 3, 4);
        let model = Gbdt::fit(&data, GbdtParams { n_rounds: 8, ..Default::default() });
        let j = Json::parse(&model.to_json().to_string()).unwrap();
        let loaded = Gbdt::from_json(&j).unwrap();
        for x in &data.x {
            assert_eq!(model.predict(x), loaded.predict(x));
            let a = model.decision_scores(x);
            let b = loaded.decision_scores(x);
            for (u, v) in a.iter().zip(b.iter()) {
                assert!((u - v).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn empty_data_is_safe() {
        let data = TabularData::new(vec![], vec![], 3);
        let model = Gbdt::fit(&data, GbdtParams::default());
        assert!(model.predict(&[0.0; 0]) < 3);
    }
}
