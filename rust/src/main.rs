//! `gnn-spmm` — leader binary: train the format predictor, run GNN training
//! under a chosen format policy, or regenerate any paper experiment.
//!
//! ```text
//! gnn-spmm train-predictor [--count 150] [--w 1.0] [--out artifacts/predictor.json]
//! gnn-spmm run --model GCN --dataset CoraFull --policy predicted|oracle|COO|CSR|...
//!              [--epochs 10] [--seed 7]
//! gnn-spmm experiment --name table1|fig1|fig2|fig3|fig6|fig7|fig8|fig9|fig10|fig11|table3
//!              [--out results/]
//! gnn-spmm info
//! ```

use gnn_spmm::coordinator::{experiments, Workbench};
use gnn_spmm::gnn::engine::StaticPolicy;
use gnn_spmm::gnn::{train, ModelKind, TrainConfig};
use gnn_spmm::predictor::policy::{OraclePolicy, PredictedPolicy};
use gnn_spmm::predictor::training::{train_predictor, TrainedPredictor, TrainingCorpus};
use gnn_spmm::sparse::Format;
use gnn_spmm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("train-predictor") => cmd_train_predictor(&args),
        Some("run") => cmd_run(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: gnn-spmm <train-predictor|run|experiment|info> [--options]\n\
                 see `rust/src/main.rs` docs for details"
            );
            Ok(())
        }
    }
}

fn cmd_train_predictor(args: &Args) -> anyhow::Result<()> {
    let count = args.get_usize("count", 150);
    let w = args.get_f64("w", 1.0);
    let seed = args.get_u64("seed", 0xC0FFEE);
    let out = args.get_or("out", "artifacts/predictor.json");
    println!("building training corpus ({count} matrices)…");
    let corpus = TrainingCorpus::build(count, 64, 512, 32, 2, seed);
    println!("training XGBoost-style GBDT (w = {w})…");
    let pred = train_predictor(&corpus, w, seed ^ 1);
    println!("cross-validated accuracy: {:.1}%", pred.cv_accuracy * 100.0);
    pred.save(std::path::Path::new(out))?;
    println!("saved predictor to {out}");
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let model = ModelKind::from_name(args.get_or("model", "GCN"))
        .ok_or_else(|| anyhow::anyhow!("unknown model (GCN|GAT|RGCN|FiLM|EGC)"))?;
    let seed = args.get_u64("seed", 7);
    let cfg = TrainConfig {
        epochs: args.get_usize("epochs", 10),
        hidden: args.get_usize("hidden", 16),
        lr: args.get_f64("lr", 0.02) as f32,
        seed,
    };
    println!("building workbench (datasets + predictor)…");
    let wb = Workbench::standard(seed);
    let ds = wb
        .dataset(args.get_or("dataset", "Cora"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;

    let policy_name = args.get_or("policy", "predicted").to_string();
    let report = match policy_name.as_str() {
        "predicted" => {
            let predictor = if let Some(path) = args.get("predictor") {
                TrainedPredictor::load(std::path::Path::new(path))?
            } else {
                experiments::clone_predictor(&wb.predictor)
            };
            let mut p = PredictedPolicy::new(predictor);
            train(model, ds, &mut p, &cfg)
        }
        "oracle" => {
            let mut p = OraclePolicy::default();
            train(model, ds, &mut p, &cfg)
        }
        other => {
            let f = Format::from_name(other)
                .ok_or_else(|| anyhow::anyhow!("unknown policy/format '{other}'"))?;
            let mut p = StaticPolicy(f);
            train(model, ds, &mut p, &cfg)
        }
    };

    println!(
        "\n{} on {} with policy {} — {:.4}s total",
        report.model, report.dataset, report.policy, report.total_time
    );
    println!("loss curve: {:?}", report.losses);
    println!(
        "final accuracy: train {:.1}%  test {:.1}%",
        report.final_train_acc * 100.0,
        report.final_test_acc * 100.0
    );
    println!("phase breakdown:");
    for (phase, secs, count) in &report.phases {
        println!("  {phase:<18} {secs:>9.4}s  ({count} calls)");
    }
    println!("format decisions:");
    for d in &report.decisions {
        println!("  {:<14} -> {:<4} (density {:.4})", d.slot, d.format, d.density);
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let name = args.get_or("name", "table1").to_string();
    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    let seed = args.get_u64("seed", 0xE8);
    let runs = args.get_usize("runs", experiments::DEFAULT_RUNS);
    let cfg = TrainConfig {
        epochs: args.get_usize("epochs", 10),
        ..Default::default()
    };
    println!("building workbench…");
    let wb = Workbench::standard(seed);
    let ws = [0.0, 0.3, 0.5, 0.7, 1.0];
    let table = match name.as_str() {
        "table1" => experiments::table1(&wb),
        "fig1" => experiments::fig1(&wb, &cfg, runs),
        "fig2" => experiments::fig2(&wb, "CoraFull", 10),
        "fig3" => experiments::fig3(&wb, &cfg, runs),
        "fig6" => experiments::fig6(&wb, &ws),
        "fig7" => experiments::fig7(&wb),
        "fig8" => experiments::fig8(&wb, &cfg, runs),
        "fig9" => experiments::fig9(&wb, &cfg, runs),
        "fig10" => experiments::fig10(&wb, &ws),
        "fig11" => experiments::fig11(&wb),
        "table3" => experiments::table3(&wb, &cfg, runs),
        other => anyhow::bail!("unknown experiment '{other}'"),
    };
    experiments::print_table(&name, &table);
    let path = out_dir.join(format!("{name}.csv"));
    table.write_file(&path)?;
    println!("\nwrote {}", path.display());
    Ok(())
}

fn cmd_info(_args: &Args) -> anyhow::Result<()> {
    println!("gnn-spmm — sparse-format selection for GNN SpMM (paper reproduction)");
    println!("formats: COO CSR CSC DIA BSR DOK LIL");
    println!("models:  GCN GAT RGCN FiLM EGC");
    println!("datasets (laptop scale):");
    for spec in gnn_spmm::graph::PAPER_DATASETS {
        let s = spec.laptop();
        println!(
            "  {:<11} n={:<6} feat={:<5} adj_density={:.2}%  classes={}",
            s.name,
            s.n,
            s.feat_dim,
            s.adj_density * 100.0,
            s.n_classes
        );
    }
    Ok(())
}
