//! Experiment coordinator: builds the standard workbench (datasets +
//! profiled corpus + trained predictor) and runs every experiment of the
//! paper's evaluation section. The bench binaries under `rust/benches/` are
//! thin wrappers over [`experiments`].

pub mod experiments;

use crate::graph::{DatasetSpec, GraphDataset, PAPER_DATASETS};
use crate::predictor::training::{train_predictor, TrainedPredictor, TrainingCorpus};
use crate::util::rng::Rng;

/// Default corpus parameters (laptop-scaled; see DESIGN.md §Substitutions).
pub const CORPUS_COUNT: usize = 150;
pub const CORPUS_MIN_N: usize = 64;
pub const CORPUS_MAX_N: usize = 512;
pub const CORPUS_D: usize = 16;
pub const CORPUS_REPS: usize = 2;

/// Everything the experiments need, built once.
pub struct Workbench {
    pub datasets: Vec<GraphDataset>,
    pub corpus: TrainingCorpus,
    pub predictor: TrainedPredictor,
    pub seed: u64,
}

impl Workbench {
    /// Standard workbench: the five Table-1 datasets at laptop scale, a
    /// profiled training corpus, and a speed-optimized (w = 1) predictor.
    pub fn standard(seed: u64) -> Workbench {
        Self::with_sizes(seed, CORPUS_COUNT, 4, 256)
    }

    /// Smaller workbench for fast tests.
    pub fn small(seed: u64) -> Workbench {
        Self::with_sizes(seed, 40, 16, 64)
    }

    /// Bench-scale workbench: datasets shrunk 8× so the full figure grid
    /// (5 models × 5 datasets × 7 formats × repeats) completes in minutes.
    /// Set `GNN_SPMM_BENCH_FULL=1` to run at the standard 4× scale instead.
    pub fn bench(seed: u64) -> Workbench {
        if std::env::var("GNN_SPMM_BENCH_FULL").is_ok() {
            Self::standard(seed)
        } else {
            Self::with_sizes(seed, 100, 8, 128)
        }
    }

    fn with_sizes(seed: u64, corpus_count: usize, shrink: usize, max_feat: usize) -> Workbench {
        let mut rng = Rng::new(seed);
        let datasets = PAPER_DATASETS
            .iter()
            .map(|spec: &DatasetSpec| GraphDataset::generate(&spec.scaled(shrink, max_feat), &mut rng))
            .collect();
        let corpus = TrainingCorpus::build(
            corpus_count,
            CORPUS_MIN_N,
            CORPUS_MAX_N.min(if corpus_count < 100 { 256 } else { CORPUS_MAX_N }),
            CORPUS_D,
            CORPUS_REPS,
            seed ^ 0xC0FFEE,
        );
        let predictor = train_predictor(&corpus, 1.0, seed ^ 0x7EA);
        Workbench { datasets, corpus, predictor, seed }
    }

    pub fn dataset(&self, name: &str) -> Option<&GraphDataset> {
        self.datasets.iter().find(|d| d.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_workbench_builds() {
        let wb = Workbench::small(1);
        assert_eq!(wb.datasets.len(), 5);
        assert!(wb.dataset("KarateClub").is_some());
        assert!(wb.dataset("Cora").is_some());
        assert!(wb.predictor.cv_accuracy > 0.2);
    }
}
