//! One driver function per table/figure of the paper's evaluation. Each
//! returns a [`CsvTable`] (written to `results/` by the bench binaries) and
//! is deterministic given the workbench seed.

use super::Workbench;
use crate::features::FEATURE_NAMES;
use crate::gnn::engine::{FormatPolicy, SlotTargetedPolicy, StaticPolicy};
use crate::gnn::{train, ModelKind, TrainConfig, ALL_MODELS};
use crate::graph::GraphDataset;
use crate::ml::gbdt::{Gbdt, GbdtParams};
use crate::ml::knn::Knn;
use crate::ml::metrics::{accuracy, kfold};
use crate::ml::mlp::{Mlp, MlpParams};
use crate::ml::svm::{Svm, SvmParams};
use crate::ml::tree::{DecisionTree, TreeParams};
use crate::ml::{Classifier, TabularData};
use crate::predictor::policy::{CnnPolicy, OraclePolicy, PredictedPolicy, TabularModelPolicy};
use crate::predictor::training::TrainedPredictor;
use crate::sparse::{Format, ALL_FORMATS};
use crate::util::csv::{fmt, CsvTable};
use crate::util::rng::Rng;
use crate::util::stats;

/// Measurement repetitions per configuration (paper: 5; default here: 3).
pub const DEFAULT_RUNS: usize = 3;

fn train_time(
    kind: ModelKind,
    ds: &GraphDataset,
    make_policy: &mut dyn FnMut() -> Box<dyn FormatPolicy>,
    cfg: &TrainConfig,
    runs: usize,
) -> (f64, f64, f64) {
    let times: Vec<f64> = (0..runs)
        .map(|_| {
            let mut policy = make_policy();
            train(kind, ds, policy.as_mut(), cfg).total_time
        })
        .collect();
    (stats::geomean(&times), stats::min(&times), stats::max(&times))
}

/// Table 1: dataset statistics.
pub fn table1(wb: &Workbench) -> CsvTable {
    let mut t = CsvTable::new(["dataset", "nodes", "adj_density_pct", "feat_dim", "feat_nnz", "classes"]);
    for ds in &wb.datasets {
        t.push([
            ds.name.clone(),
            ds.adj.rows.to_string(),
            fmt(ds.adj.density() * 100.0, 3),
            ds.features.cols.to_string(),
            ds.features.nnz().to_string(),
            ds.n_classes.to_string(),
        ]);
    }
    t
}

/// Fig. 1: best static format per dataset (GCN end-to-end training time,
/// normalized against COO).
pub fn fig1(wb: &Workbench, cfg: &TrainConfig, runs: usize) -> CsvTable {
    let mut t = CsvTable::new(["dataset", "format", "time_s", "speedup_vs_coo", "is_best"]);
    for ds in &wb.datasets {
        let mut rows: Vec<(Format, f64)> = Vec::new();
        for &fmtc in &ALL_FORMATS {
            let (time, _, _) = train_time(
                ModelKind::Gcn,
                ds,
                &mut || Box::new(StaticPolicy(fmtc)),
                cfg,
                runs,
            );
            rows.push((fmtc, time));
        }
        let coo_time = rows.iter().find(|(f, _)| *f == Format::Coo).unwrap().1;
        let best = rows
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
            .0;
        for (f, time) in &rows {
            t.push([
                ds.name.clone(),
                f.name().to_string(),
                fmt(*time, 4),
                fmt(coo_time / time, 3),
                (*f == best).to_string(),
            ]);
        }
    }
    t
}

/// Fig. 2: density drift — k-hop effective-propagation density plus the
/// GCN layer-1 activation density per training epoch.
pub fn fig2(wb: &Workbench, dataset: &str, epochs: usize) -> CsvTable {
    let ds = wb.dataset(dataset).expect("dataset");
    let mut t = CsvTable::new(["series", "step", "density"]);
    for k in 1..=4usize {
        let d = crate::graph::khop_density(&ds.adj, k);
        t.push(["khop_adjacency".to_string(), k.to_string(), fmt(d, 5)]);
    }
    let mut policy = StaticPolicy(Format::Csr);
    let report = train(
        ModelKind::Gcn,
        ds,
        &mut policy,
        &TrainConfig { epochs, ..Default::default() },
    );
    for (epoch, d) in report.h1_densities.iter().enumerate() {
        t.push(["gcn_h1_activation".to_string(), (epoch + 1).to_string(), fmt(*d, 5)]);
    }
    t
}

/// Fig. 3: speedup over COO when only the layer-1 output (H1) is stored in
/// a given format (the rest stays COO), on two contrast datasets.
pub fn fig3(wb: &Workbench, cfg: &TrainConfig, runs: usize) -> CsvTable {
    let mut t = CsvTable::new(["dataset", "h1_format", "time_s", "speedup_vs_coo"]);
    for name in ["CoraFull", "PubmedFull"] {
        let ds = wb.dataset(name).expect("dataset");
        let (coo_time, _, _) = train_time(
            ModelKind::Gcn,
            ds,
            &mut || Box::new(StaticPolicy(Format::Coo)),
            cfg,
            runs,
        );
        for &fmtc in &ALL_FORMATS {
            let (time, _, _) = train_time(
                ModelKind::Gcn,
                ds,
                &mut || {
                    Box::new(SlotTargetedPolicy {
                        needle: "H1",
                        special: fmtc,
                        default: Format::Coo,
                    })
                },
                cfg,
                runs,
            );
            t.push([
                name.to_string(),
                fmtc.name().to_string(),
                fmt(time, 4),
                fmt(coo_time / time, 3),
            ]);
        }
    }
    t
}

/// Fig. 6: how often each format is Eq-1-optimal on the training corpus as
/// `w` varies.
pub fn fig6(wb: &Workbench, ws: &[f64]) -> CsvTable {
    let mut t = CsvTable::new(["w", "format", "optimal_count", "optimal_pct"]);
    let total = wb.corpus.matrices.len() as f64;
    for &w in ws {
        for (f, count) in wb.corpus.label_frequency(w) {
            t.push([
                fmt(w, 2),
                f.name().to_string(),
                count.to_string(),
                fmt(count as f64 / total * 100.0, 1),
            ]);
        }
    }
    t
}

/// Fig. 7: leave-one-out feature importance — accuracy drop when each
/// Table-2 feature is removed (plus the GBDT's own gain importance).
pub fn fig7(wb: &Workbench) -> CsvTable {
    let (data, _) = wb.corpus.dataset(1.0);
    let base_acc = cv_acc(&data, wb.seed);
    let gain = Gbdt::fit(&data, GbdtParams::default()).importance();
    let mut rows: Vec<(usize, f64)> = (0..FEATURE_NAMES.len())
        .map(|drop_idx| {
            let reduced = TabularData::new(
                data.x
                    .iter()
                    .map(|row| {
                        row.iter()
                            .enumerate()
                            .filter(|(j, _)| *j != drop_idx)
                            .map(|(_, &v)| v)
                            .collect()
                    })
                    .collect(),
                data.y.clone(),
                data.n_classes,
            );
            (drop_idx, (base_acc - cv_acc(&reduced, wb.seed)).max(0.0))
        })
        .collect();
    rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let drop_total: f64 = rows.iter().map(|(_, d)| d).sum::<f64>().max(1e-9);
    let mut t = CsvTable::new(["feature", "loo_importance_pct", "gain_importance_pct", "rank"]);
    for (rank, (idx, drop)) in rows.iter().enumerate() {
        t.push([
            FEATURE_NAMES[*idx].to_string(),
            fmt(drop / drop_total * 100.0, 2),
            fmt(gain[*idx] * 100.0, 2),
            (rank + 1).to_string(),
        ]);
    }
    t
}

fn cv_acc(data: &TabularData, seed: u64) -> f64 {
    crate::predictor::training::cross_validate_gbdt(data, 5, seed)
}

/// Fig. 8: end-to-end speedup of the predicted policy over always-COO, per
/// model × dataset (8a aggregates per model, 8b per dataset).
pub fn fig8(wb: &Workbench, cfg: &TrainConfig, runs: usize) -> CsvTable {
    let mut t = CsvTable::new(["model", "dataset", "coo_time_s", "pred_time_s", "speedup", "min_speedup", "max_speedup"]);
    for &kind in &ALL_MODELS {
        for ds in &wb.datasets {
            let (coo_time, _, _) = train_time(
                kind,
                ds,
                &mut || Box::new(StaticPolicy(Format::Coo)),
                cfg,
                runs,
            );
            let times: Vec<f64> = (0..runs)
                .map(|_| {
                    let predictor = clone_predictor(&wb.predictor);
                    let mut policy = PredictedPolicy::new(predictor);
                    train(kind, ds, &mut policy, cfg).total_time
                })
                .collect();
            let pred_time = stats::geomean(&times);
            t.push([
                kind.name().to_string(),
                ds.name.clone(),
                fmt(coo_time, 4),
                fmt(pred_time, 4),
                fmt(coo_time / pred_time, 3),
                fmt(coo_time / stats::max(&times), 3),
                fmt(coo_time / stats::min(&times), 3),
            ]);
        }
    }
    t
}

/// Fig. 9: predicted-policy time as a fraction of oracle time per model.
pub fn fig9(wb: &Workbench, cfg: &TrainConfig, runs: usize) -> CsvTable {
    let mut t = CsvTable::new(["model", "dataset", "oracle_time_s", "pred_time_s", "pct_of_oracle"]);
    for &kind in &ALL_MODELS {
        for ds in &wb.datasets {
            let (oracle_time, _, _) = train_time(
                kind,
                ds,
                &mut || Box::new(OraclePolicy { reps: 2, w: 1.0 }),
                cfg,
                runs,
            );
            let (pred_time, _, _) = train_time(
                kind,
                ds,
                &mut || Box::new(PredictedPolicy::new(clone_predictor(&wb.predictor))),
                cfg,
                runs,
            );
            // "% of oracle performance": oracle_time / pred_time (≤ 1 when
            // the oracle is faster).
            t.push([
                kind.name().to_string(),
                ds.name.clone(),
                fmt(oracle_time, 4),
                fmt(pred_time, 4),
                fmt(oracle_time / pred_time * 100.0, 1),
            ]);
        }
    }
    t
}

/// Fig. 10: prediction accuracy as the optimization weight `w` varies.
pub fn fig10(wb: &Workbench, ws: &[f64]) -> CsvTable {
    let mut t = CsvTable::new(["w", "cv_accuracy_pct"]);
    for &w in ws {
        let (data, _) = wb.corpus.dataset(w);
        t.push([fmt(w, 2), fmt(cv_acc(&data, wb.seed) * 100.0, 1)]);
    }
    t
}

/// Fig. 11: XGBoost vs MLP / KNN / SVM — CV accuracy and per-sample
/// inference time.
pub fn fig11(wb: &Workbench) -> CsvTable {
    let (data, _) = wb.corpus.dataset(1.0);
    let mut rng = Rng::new(wb.seed ^ 0xF16);
    let folds = kfold(data.len(), 5, &mut rng);

    let mut t = CsvTable::new(["model", "cv_accuracy_pct", "inference_us_per_sample"]);
    type FitFn = Box<dyn Fn(&TabularData) -> Box<dyn Classifier>>;
    let fits: Vec<(&str, FitFn)> = vec![
        ("XGBoost", Box::new(|d: &TabularData| Box::new(Gbdt::fit(d, GbdtParams::default())) as Box<dyn Classifier>)),
        ("MLP", Box::new(|d: &TabularData| Box::new(Mlp::fit(d, MlpParams { epochs: 60, ..Default::default() })) as Box<dyn Classifier>)),
        ("KNN", Box::new(|d: &TabularData| Box::new(Knn::fit(d, 1)) as Box<dyn Classifier>)),
        ("SVM", Box::new(|d: &TabularData| Box::new(Svm::fit(d, SvmParams::default())) as Box<dyn Classifier>)),
    ];
    for (name, fit) in &fits {
        let mut accs = Vec::new();
        for (tr, te) in &folds {
            let model = fit(&data.subset(tr));
            let test = data.subset(te);
            accs.push(accuracy(&model.predict_batch(&test.x), &test.y));
        }
        // Inference time on the full set.
        let model = fit(&data);
        let samples = crate::util::timer::time_n(1, 3, || model.predict_batch(&data.x));
        let per_sample_us = stats::median(&samples) / data.len() as f64 * 1e6;
        t.push([
            name.to_string(),
            fmt(stats::mean(&accs) * 100.0, 1),
            fmt(per_sample_us, 3),
        ]);
    }
    t
}

/// Table 3: XGBoost vs CNN [45,24] vs decision tree [27] — inference time,
/// prediction accuracy, and realized GNN speedup.
pub fn table3(wb: &Workbench, cfg: &TrainConfig, runs: usize) -> CsvTable {
    let (data, norm) = wb.corpus.dataset(1.0);
    let labels = wb.corpus.labels(1.0);
    let mut rng = Rng::new(wb.seed ^ 0x7AB3);
    let folds = kfold(data.len(), 5, &mut rng);

    // --- accuracies ---
    let mut gbdt_accs = Vec::new();
    let mut tree_accs = Vec::new();
    let mut cnn_accs = Vec::new();
    for (tr, te) in &folds {
        let train_d = data.subset(tr);
        let test_d = data.subset(te);
        let g = Gbdt::fit(&train_d, GbdtParams::default());
        gbdt_accs.push(accuracy(&g.predict_batch(&test_d.x), &test_d.y));
        let dt = DecisionTree::fit(&train_d, TreeParams::default());
        tree_accs.push(accuracy(&dt.predict_batch(&test_d.x), &test_d.y));
        // CNN trains on thumbnails.
        let tr_imgs: Vec<Vec<f32>> = tr.iter().map(|&i| wb.corpus.thumbnails[i].clone()).collect();
        let tr_labels: Vec<usize> = tr.iter().map(|&i| labels[i]).collect();
        let cnn = crate::ml::cnn::Cnn::fit(
            &tr_imgs,
            &tr_labels,
            ALL_FORMATS.len(),
            crate::ml::cnn::CnnParams { epochs: 12, ..Default::default() },
        );
        let correct = te
            .iter()
            .filter(|&&i| cnn.predict_image(&wb.corpus.thumbnails[i]) == labels[i])
            .count();
        cnn_accs.push(correct as f64 / te.len() as f64);
    }

    // --- inference times ---
    let gbdt = Gbdt::fit(&data, GbdtParams::default());
    let dt = DecisionTree::fit(&data, TreeParams::default());
    let cnn = crate::ml::cnn::Cnn::fit(
        &wb.corpus.thumbnails,
        &labels,
        ALL_FORMATS.len(),
        crate::ml::cnn::CnnParams { epochs: 12, ..Default::default() },
    );
    let t_gbdt = stats::median(&crate::util::timer::time_n(1, 3, || gbdt.predict_batch(&data.x)))
        / data.len() as f64;
    let t_dt = stats::median(&crate::util::timer::time_n(1, 3, || dt.predict_batch(&data.x)))
        / data.len() as f64;
    let t_cnn = stats::median(&crate::util::timer::time_n(1, 3, || {
        wb.corpus.thumbnails.iter().map(|img| cnn.predict_image(img)).collect::<Vec<_>>()
    })) / data.len() as f64;

    // --- realized speedups (GCN across datasets, geomean) ---
    let realized = |mk_policy: &mut dyn FnMut() -> Box<dyn FormatPolicy>| -> f64 {
        let mut speedups = Vec::new();
        for ds in &wb.datasets {
            let (coo_time, _, _) = train_time(
                ModelKind::Gcn,
                ds,
                &mut || Box::new(StaticPolicy(Format::Coo)),
                cfg,
                runs,
            );
            let (ptime, _, _) = train_time(ModelKind::Gcn, ds, mk_policy, cfg, runs);
            speedups.push(coo_time / ptime);
        }
        stats::geomean(&speedups)
    };
    let sp_gbdt = realized(&mut || Box::new(PredictedPolicy::new(clone_predictor(&wb.predictor))));
    let sp_dt = realized(&mut || {
        Box::new(TabularModelPolicy {
            model: DecisionTree::fit(&data, TreeParams::default()),
            norm: norm.clone(),
            label: "decision-tree",
        })
    });
    let sp_cnn = realized(&mut || {
        Box::new(CnnPolicy {
            cnn: crate::ml::cnn::Cnn::fit(
                &wb.corpus.thumbnails,
                &labels,
                ALL_FORMATS.len(),
                crate::ml::cnn::CnnParams { epochs: 12, ..Default::default() },
            ),
        })
    });

    let mut t = CsvTable::new(["model", "inference_s", "accuracy_pct", "realized_speedup"]);
    t.push([
        "XGBoost (ours)".to_string(),
        fmt(t_gbdt, 7),
        fmt(stats::mean(&gbdt_accs) * 100.0, 1),
        fmt(sp_gbdt, 3),
    ]);
    t.push([
        "CNN [45,24]".to_string(),
        fmt(t_cnn, 7),
        fmt(stats::mean(&cnn_accs) * 100.0, 1),
        fmt(sp_cnn, 3),
    ]);
    t.push([
        "Decision-Tree [27]".to_string(),
        fmt(t_dt, 7),
        fmt(stats::mean(&tree_accs) * 100.0, 1),
        fmt(sp_dt, 3),
    ]);
    t
}

/// Clone a trained predictor via JSON round-trip (Gbdt holds no Rc/refs).
pub fn clone_predictor(p: &TrainedPredictor) -> TrainedPredictor {
    TrainedPredictor::from_json(&p.to_json()).expect("predictor round-trip")
}

/// Pretty-print a CsvTable to stdout in aligned columns.
pub fn print_table(title: &str, t: &CsvTable) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = t.header.iter().map(|h| h.len()).collect();
    for row in &t.rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let print_row = |row: &[String]| {
        let cells: Vec<String> = row
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{c:<w$}", w = widths[i]))
            .collect();
        println!("  {}", cells.join("  "));
    };
    print_row(&t.header);
    for row in &t.rows {
        print_row(row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_and_fig10_shapes() {
        let wb = Workbench::small(3);
        let f6 = fig6(&wb, &[0.0, 1.0]);
        assert_eq!(f6.rows.len(), 2 * ALL_FORMATS.len());
        let f10 = fig10(&wb, &[0.0, 1.0]);
        assert_eq!(f10.rows.len(), 2);
        for row in &f10.rows {
            let acc: f64 = row[1].parse().unwrap();
            assert!(acc > 100.0 / 7.0, "better than chance: {acc}");
        }
    }

    #[test]
    fn table1_lists_all_datasets() {
        let wb = Workbench::small(4);
        let t = table1(&wb);
        assert_eq!(t.rows.len(), 5);
    }
}
