//! Integration: the experiment coordinator regenerates the paper's
//! tables/figures end-to-end on a reduced workbench — the same code paths
//! the bench binaries use, validated for shape and internal consistency.

use gnn_spmm::coordinator::{experiments, Workbench};
use gnn_spmm::gnn::TrainConfig;
use gnn_spmm::sparse::ALL_FORMATS;

fn wb() -> Workbench {
    Workbench::small(0xBEE)
}

fn fast_cfg() -> TrainConfig {
    TrainConfig { epochs: 3, hidden: 8, ..Default::default() }
}

#[test]
fn table1_and_fig6_and_fig10() {
    let wb = wb();
    let t1 = experiments::table1(&wb);
    assert_eq!(t1.rows.len(), 5);

    let f6 = experiments::fig6(&wb, &[0.0, 0.5, 1.0]);
    assert_eq!(f6.rows.len(), 3 * ALL_FORMATS.len());
    // Counts per w sum to the corpus size.
    let per_w: usize = f6.rows[..ALL_FORMATS.len()]
        .iter()
        .map(|r| r[2].parse::<usize>().unwrap())
        .sum();
    assert_eq!(per_w, wb.corpus.matrices.len());

    let f10 = experiments::fig10(&wb, &[0.0, 1.0]);
    for row in &f10.rows {
        let acc: f64 = row[1].parse().unwrap();
        assert!(acc > 14.3, "accuracy must beat 7-class chance: {acc}");
    }
}

#[test]
fn fig2_density_series_monotone_khop() {
    let wb = wb();
    let f2 = experiments::fig2(&wb, "Cora", 4);
    let khop: Vec<f64> = f2
        .rows
        .iter()
        .filter(|r| r[0] == "khop_adjacency")
        .map(|r| r[2].parse().unwrap())
        .collect();
    assert!(khop.len() >= 3);
    for w in khop.windows(2) {
        assert!(w[1] >= w[0] - 1e-12, "k-hop density must grow: {khop:?}");
    }
    let h1: Vec<f64> = f2
        .rows
        .iter()
        .filter(|r| r[0] == "gcn_h1_activation")
        .map(|r| r[2].parse().unwrap())
        .collect();
    assert_eq!(h1.len(), 4);
}

#[test]
fn fig1_reports_all_formats_and_flags_best() {
    let wb = wb();
    // Restrict to the two smallest datasets for test speed by building a
    // tiny view of the workbench.
    let mut small = wb;
    small.datasets.retain(|d| d.name == "KarateClub" || d.name == "Cora");
    let f1 = experiments::fig1(&small, &fast_cfg(), 1);
    assert_eq!(f1.rows.len(), 2 * ALL_FORMATS.len());
    for name in ["KarateClub", "Cora"] {
        let best_rows = f1
            .rows
            .iter()
            .filter(|r| r[0] == name && r[4] == "true")
            .count();
        assert_eq!(best_rows, 1, "{name} needs exactly one best format");
    }
}

#[test]
fn fig8_and_fig9_consistency() {
    let mut wb = wb();
    wb.datasets.retain(|d| d.name == "Cora");
    let f8 = experiments::fig8(&wb, &fast_cfg(), 1);
    assert_eq!(f8.rows.len(), 5); // 5 models × 1 dataset
    for row in &f8.rows {
        let speedup: f64 = row[4].parse().unwrap();
        assert!(speedup > 0.1 && speedup < 50.0, "sane speedup: {speedup}");
    }
    let f9 = experiments::fig9(&wb, &fast_cfg(), 1);
    assert_eq!(f9.rows.len(), 5);
    for row in &f9.rows {
        let pct: f64 = row[4].parse().unwrap();
        assert!(pct > 5.0, "oracle ratio in sane range: {pct}");
    }
}

#[test]
fn fig11_compares_four_models() {
    let wb = wb();
    let f11 = experiments::fig11(&wb);
    let names: Vec<&str> = f11.rows.iter().map(|r| r[0].as_str()).collect();
    assert_eq!(names, vec!["XGBoost", "MLP", "KNN", "SVM"]);
    for row in &f11.rows {
        let acc: f64 = row[1].parse().unwrap();
        assert!(acc >= 0.0 && acc <= 100.0);
        let us: f64 = row[2].parse().unwrap();
        assert!(us > 0.0);
    }
}
