//! Shared helpers for the pool/scheduling integration tests. Files in
//! `tests/common/` are not compiled as test binaries; the thread-count
//! pinned binaries (`pool_threads1.rs`, `pool_threads4.rs`) include this via
//! `mod common;`.

use gnn_spmm::sparse::{Coo, Schedule, SparseMatrix, Split, ThreadCap, Tile, ALL_FORMATS};
use gnn_spmm::tensor::Matrix;
use gnn_spmm::util::rng::Rng;

/// Random COO with a dense hub row and hub column on top of uniform noise —
/// the degree skew that breaks count-based row partitioning.
pub fn skewed_coo(rng: &mut Rng, n: usize, m: usize) -> Coo {
    let mut triples = Vec::new();
    for c in 0..m {
        if rng.bernoulli(0.8) {
            triples.push((0, c as u32, rng.uniform(-1.0, 1.0) as f32));
        }
    }
    for r in 0..n {
        if rng.bernoulli(0.8) {
            triples.push((r as u32, 0, rng.uniform(-1.0, 1.0) as f32));
        }
    }
    for r in 0..n {
        for c in 0..m {
            if rng.bernoulli(0.05) {
                triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
            }
        }
    }
    Coo::from_triples(n, m, triples)
}

/// All seven formats' `spmm_into`/`spmm_t_into` against the dense
/// reference, on hub-skewed inputs, with stale output buffers that the
/// kernels must fully overwrite. Widths cover the narrow fallback (d < 16),
/// the exact-tile case and tile + remainder.
pub fn check_formats_vs_dense() {
    let mut rng = Rng::new(0xF00D);
    for &(n, m, d) in &[(33usize, 47usize, 5usize), (64, 64, 16), (80, 70, 40)] {
        let coo = skewed_coo(&mut rng, n, m);
        let dense = coo.to_dense();
        let x = Matrix::rand(m, d, &mut rng);
        let xt = Matrix::rand(n, d, &mut rng);
        let want = dense.matmul(&x);
        let want_t = dense.transpose().matmul(&xt);
        let base = SparseMatrix::Coo(coo);
        for &fmt in &ALL_FORMATS {
            let Ok(mm) = base.convert(fmt) else {
                continue; // DIA over budget on scattered patterns
            };
            let mut out = Matrix::full(n, d, 123.0);
            mm.spmm_into(&x, &mut out);
            assert!(
                out.max_abs_diff(&want) < 1e-3,
                "{} spmm_into ({n},{m},{d})",
                fmt.name()
            );
            let mut out_t = Matrix::full(m, d, 123.0);
            mm.spmm_t_into(&xt, &mut out_t);
            assert!(
                out_t.max_abs_diff(&want_t) < 1e-3,
                "{} spmm_t_into ({n},{m},{d})",
                fmt.name()
            );
        }
    }
}

/// Every (format × tile × split × cap) kernel variant against the dense
/// reference under whatever thread pin the including binary set: the full
/// schedule space must agree with dense math regardless of how many pool
/// workers exist. Hub-skewed inputs, stale output buffers, widths spanning
/// the sub-tile fallback through tile + remainder.
pub fn check_schedules_vs_dense() {
    let mut rng = Rng::new(0x5EED_F00D);
    for &(n, m, d) in &[(33usize, 47usize, 5usize), (64, 64, 16), (80, 70, 40)] {
        let coo = skewed_coo(&mut rng, n, m);
        let dense = coo.to_dense();
        let x = Matrix::rand(m, d, &mut rng);
        let xt = Matrix::rand(n, d, &mut rng);
        let want = dense.matmul(&x);
        let want_t = dense.transpose().matmul(&xt);
        let base = SparseMatrix::Coo(coo);
        for &fmt in &ALL_FORMATS {
            let Ok(mm) = base.convert(fmt) else {
                continue; // DIA over budget on scattered patterns
            };
            for tile in Tile::ALL {
                for split in Split::ALL {
                    for threads in [ThreadCap::Auto, ThreadCap::Cap(1), ThreadCap::Cap(3)] {
                        let sched = Schedule { tile, split, threads };
                        let mut out = Matrix::full(n, d, 123.0);
                        mm.spmm_into_with(&x, &mut out, sched);
                        assert!(
                            out.max_abs_diff(&want) < 1e-3,
                            "{} {} spmm_into ({n},{m},{d})",
                            fmt.name(),
                            sched.label()
                        );
                        let mut out_t = Matrix::full(m, d, -321.0);
                        mm.spmm_t_into_with(&xt, &mut out_t, sched);
                        assert!(
                            out_t.max_abs_diff(&want_t) < 1e-3,
                            "{} {} spmm_t_into ({n},{m},{d})",
                            fmt.name(),
                            sched.label()
                        );
                    }
                }
            }
        }
    }
}
