//! Single-threaded execution pin: `GNN_SPMM_THREADS=1` forces every pool
//! dispatch onto the serial fallback paths (no lease, direct scatter into
//! the output). The env var is set before the pool's one-time
//! initialization — this file is its own process, so the pin cannot race
//! with other test binaries.

mod common;

#[test]
fn formats_match_dense_single_thread() {
    std::env::set_var("GNN_SPMM_THREADS", "1");
    assert_eq!(gnn_spmm::util::parallel::num_threads(), 1);
    common::check_formats_vs_dense();
}

/// The full schedule space under the single-thread pin: every
/// (format × tile × split × cap) variant must agree with dense math when
/// the pool has no workers at all — `tasks_for` collapses every split to
/// one span and the serial fallback runs the monomorphized tile kernels.
#[test]
fn schedule_space_matches_dense_single_thread() {
    std::env::set_var("GNN_SPMM_THREADS", "1");
    assert_eq!(gnn_spmm::util::parallel::num_threads(), 1);
    common::check_schedules_vs_dense();
}
