//! Single-threaded execution pin: `GNN_SPMM_THREADS=1` forces every pool
//! dispatch onto the serial fallback paths (no lease, direct scatter into
//! the output). The env var is set before the pool's one-time
//! initialization — this file is its own process, so the pin cannot race
//! with other test binaries.

mod common;

#[test]
fn formats_match_dense_single_thread() {
    std::env::set_var("GNN_SPMM_THREADS", "1");
    assert_eq!(gnn_spmm::util::parallel::num_threads(), 1);
    common::check_formats_vs_dense();
}
