//! Integration tests for the persistent worker pool and nnz-balanced
//! scheduling layer (`util::pool` + `util::parallel`), at the ambient
//! thread count. Thread-count-pinned kernel checks live in
//! `pool_threads1.rs` / `pool_threads4.rs` (own processes).

use gnn_spmm::sparse::{Coo, Csr};
use gnn_spmm::tensor::Matrix;
use gnn_spmm::util::parallel::{
    indptr_span, num_threads, parallel_map, parallel_ranges, split_ranges_by_weight,
};
use gnn_spmm::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

/// `split_ranges_by_weight` must return exactly `parts` abutting ranges
/// covering `[0, n)` for any weight profile: random, all-zero (degenerate),
/// and hub-dominated.
#[test]
fn prop_weight_split_covers_exactly() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..300 {
        let n = 1 + rng.gen_range(60);
        let parts = 1 + rng.gen_range(10);
        let mode = case % 4;
        let weights: Vec<usize> = (0..n)
            .map(|i| match mode {
                0 => 0,                                                // degenerate
                1 => rng.gen_range(6),                                 // random (zeros included)
                2 => if i == n / 2 { 100_000 } else { rng.gen_range(2) } // hub-dominated
                _ => 1,                                                // uniform
            })
            .collect();
        let spans = split_ranges_by_weight(n, parts, |i| weights[i]);
        assert_eq!(spans.len(), parts, "n={n} parts={parts} mode={mode}");
        let mut next = 0;
        for s in &spans {
            assert_eq!(s.start, next, "n={n} parts={parts} mode={mode}");
            assert!(s.end >= s.start);
            next = s.end;
        }
        assert_eq!(next, n, "n={n} parts={parts} mode={mode}");
    }
    // n = 0 still yields full (empty) coverage.
    let spans = split_ranges_by_weight(0, 3, |_| 1);
    assert_eq!(spans.len(), 3);
    assert!(spans.iter().all(|s| s.is_empty()));
}

/// `indptr_span` boundaries must abut and cover all units, for indptrs with
/// empty rows, hub rows and zero total weight.
#[test]
fn prop_indptr_span_covers_exactly() {
    let mut rng = Rng::new(0xCAFE);
    for case in 0..300 {
        let n = 1 + rng.gen_range(50);
        let parts = 1 + rng.gen_range(9);
        let mode = case % 3;
        let mut indptr = vec![0usize; n + 1];
        for i in 0..n {
            let w = match mode {
                0 => 0,
                1 => rng.gen_range(7),
                _ => if i == 0 { 5_000 } else { rng.gen_range(3) },
            };
            indptr[i + 1] = indptr[i] + w;
        }
        let mut next = 0;
        for i in 0..parts {
            let s = indptr_span(&indptr, parts, i);
            assert_eq!(s.start, next, "n={n} parts={parts} mode={mode} i={i}");
            assert!(s.end >= s.start);
            next = s.end;
        }
        assert_eq!(next, n, "n={n} parts={parts} mode={mode}");
    }
    // Degenerate: empty indptr (zero units).
    assert_eq!(indptr_span(&[0usize], 4, 2), 0..0);
}

/// The hub row must not drag half the matrix onto one worker: with a
/// two-way split of a hub-dominated indptr, the hub's span holds the hub
/// and little else.
#[test]
fn indptr_span_isolates_hubs() {
    // Row 0 carries 900 of 1000 nnz; rows 1..=100 carry 1 each.
    let mut indptr = vec![0usize; 102];
    indptr[1] = 900;
    for i in 1..=100 {
        indptr[i + 1] = indptr[i] + 1;
    }
    let a = indptr_span(&indptr, 2, 0);
    let b = indptr_span(&indptr, 2, 1);
    assert_eq!(a, 0..1, "hub row sits alone in the first span");
    assert_eq!(b, 1..101);
}

#[test]
fn pool_reuse_across_sequential_calls() {
    // Many back-to-back jobs: parked workers must wake, drain and re-park
    // correctly every time, with no cross-job state leakage.
    for round in 0..40 {
        let sum = AtomicU64::new(0);
        parallel_ranges(2_000, |r| {
            let mut local = 0u64;
            for i in r {
                local += i as u64;
            }
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 1_999 * 2_000 / 2, "round {round}");
    }
}

#[test]
fn nested_spmm_inside_parallel_map() {
    // A pool task that itself runs SpMM (which tries to go parallel) must
    // degrade to inline serial execution and still be correct — the
    // training labeler does exactly this shape of nesting.
    let mut rng = Rng::new(7);
    let mut triples = Vec::new();
    for r in 0..40u32 {
        for c in 0..40u32 {
            if rng.bernoulli(0.15) {
                triples.push((r, c, rng.uniform(-1.0, 1.0) as f32));
            }
        }
    }
    let coo = Coo::from_triples(40, 40, triples);
    let csr = Csr::from_coo(&coo);
    let x = Matrix::rand(40, 20, &mut rng);
    let want = coo.to_dense().matmul(&x);

    let results = parallel_map(8, |i| {
        let mut out = Matrix::full(40, 20, 99.0);
        csr.spmm_into(&x, &mut out);
        (i, out)
    });
    assert_eq!(results.len(), 8);
    for (i, out) in &results {
        assert!(out.max_abs_diff(&want) < 1e-4, "task {i}");
    }
}

#[test]
fn weighted_spmm_matches_dense_on_powerlaw() {
    // End-to-end: a power-law-ish matrix through the weighted CSR kernels
    // at the ambient thread count.
    let mut rng = Rng::new(13);
    let n = 200;
    let mut triples = Vec::new();
    for _ in 0..4_000 {
        let r = rng.powerlaw(n, 2.1) as u32;
        let c = rng.gen_range(n) as u32;
        triples.push((r, c, rng.uniform(0.1, 1.0) as f32));
    }
    let coo = Coo::from_triples(n, n, triples);
    let csr = Csr::from_coo(&coo);
    let x = Matrix::rand(n, 33, &mut rng); // tiles + remainder
    let want = coo.to_dense().matmul(&x);
    let mut out = Matrix::full(n, 33, -5.0);
    csr.spmm_into(&x, &mut out);
    assert!(out.max_abs_diff(&want) < 1e-3);

    let want_t = coo.to_dense().transpose().matmul(&x);
    let mut out_t = Matrix::full(n, 33, -5.0);
    csr.spmm_t_into(&x, &mut out_t);
    assert!(out_t.max_abs_diff(&want_t) < 1e-3);
}

/// §Fault-Tolerance audit: a panicking pooled job must cost exactly its
/// own caller — re-raised once there — and leave the pool fully serviceable
/// *in parallel*. Before the lease-poisoning fix, the unwound caller
/// poisoned the lease mutex and every later job silently ran serial.
#[test]
fn pool_survives_panicking_jobs() {
    use gnn_spmm::util::pool::global;
    for round in 0..5 {
        // The last task of each doomed job panics (last, so the count below
        // holds even when the job degrades to inline serial execution); the
        // publisher must see the panic re-raised — not a hang, not a
        // swallowed success.
        let before = AtomicU64::new(0);
        let doomed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            global().run_weighted_ranges(
                8,
                |i| i..i + 1,
                |span| {
                    if span.start == 7 {
                        panic!("fault injection: pooled job");
                    }
                    before.fetch_add(span.len() as u64, Ordering::Relaxed);
                },
            );
        }));
        assert!(doomed.is_err(), "round {round}: the task panic must reach the publisher");
        assert_eq!(
            before.load(Ordering::Relaxed),
            7,
            "round {round}: the other tasks all ran exactly once"
        );

        // The very next job on the same pool must run to completion with
        // consistent accounting (every unit covered exactly once).
        let sum = AtomicU64::new(0);
        global().run_ranges(1_000, |r| {
            let mut local = 0u64;
            for i in r {
                local += i as u64;
            }
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1_000 / 2, "round {round}");

        // scatter_reduce (which takes the same lease twice and swaps the
        // scratch registry) must also still be coherent.
        let (n, d) = (32, 2);
        let mut out = vec![5.0f32; n * d];
        let k = num_threads().min(4).max(2);
        global().scatter_reduce(
            &mut out,
            n,
            d,
            k,
            |i| gnn_spmm::util::parallel::even_range(n, k, i),
            |span, buf| {
                for u in span {
                    buf[u * d] += 2.0;
                }
            },
        );
        for r in 0..n {
            assert_eq!(out[r * d], 2.0, "round {round} row {r}");
            assert_eq!(out[r * d + 1], 0.0, "round {round} row {r}");
        }
    }
}

#[test]
fn thread_count_is_stable() {
    // The OnceLock-backed count must be identical on every read, including
    // concurrent first reads (the old AtomicUsize version could race its
    // env re-read).
    let first = num_threads();
    let reads = parallel_map(16, |_| num_threads());
    assert!(reads.iter().all(|&n| n == first));
    assert!(first >= 1);
}
