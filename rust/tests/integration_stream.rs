//! Streaming-durability integration (DESIGN.md §Streaming-Durability).
//!
//! The load-bearing test is the **crash-ordinal sweep**: one scripted
//! `CrashPoint` per run, swept across every durability seam a randomized
//! insert/delete/reweight stream (with interleaved compactions) reaches —
//! WAL appends, checkpoint renames, compaction publishes. After each
//! simulated death the store is dropped and re-opened (the recovery
//! path), the acknowledged watermark must never regress, and once the
//! remaining ops are driven in, every merged row read must be
//! **bit-identical** to the fault-free run. Around it: fault-free
//! equivalence against an in-memory reference, short-write/I-O-error
//! retry equivalence, compactor crash-loop → degraded-mode backpressure
//! with live reads, the serve hand-off, and the predictor re-decide on a
//! compaction publish.

use gnn_spmm::gnn::engine::StaticPolicy;
use gnn_spmm::gnn::{AdjEngine, ModelKind};
use gnn_spmm::graph::stream::{EdgeOp, StreamConfig, StreamError, StreamStore};
use gnn_spmm::graph::{DatasetSpec, GraphDataset};
use gnn_spmm::serve::{train_template, EngineSnapshot, InferenceServer, ServeConfig};
use gnn_spmm::sparse::Format;
use gnn_spmm::testing::{FaultKind, FaultPlan};
use gnn_spmm::util::rng::Rng;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

const N: usize = 12;

fn dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("gnn_spmm_stream_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic mixed op stream: inserts dominate early so deletes and
/// reweights have edges to hit.
fn scripted_ops(count: usize, seed: u64) -> Vec<EdgeOp> {
    let mut rng = Rng::new(seed);
    let mut present: Vec<(u32, u32)> = Vec::new();
    let mut ops = Vec::with_capacity(count);
    while ops.len() < count {
        let roll = rng.next_f64();
        if !present.is_empty() && roll < 0.2 {
            let (src, dst) = present.swap_remove(rng.gen_range(present.len()));
            ops.push(EdgeOp::Delete { src, dst });
        } else if !present.is_empty() && roll < 0.4 {
            let &(src, dst) = &present[rng.gen_range(present.len())];
            ops.push(EdgeOp::Reweight { src, dst, w: rng.uniform(0.1, 4.0) as f32 });
        } else {
            let src = rng.gen_range(N) as u32;
            let dst = rng.gen_range(N) as u32;
            if !present.contains(&(src, dst)) {
                present.push((src, dst));
            }
            ops.push(EdgeOp::Insert { src, dst, w: rng.uniform(0.1, 4.0) as f32 });
        }
    }
    ops
}

fn apply_reference(m: &mut BTreeMap<(u32, u32), f32>, op: &EdgeOp) {
    match *op {
        EdgeOp::Insert { src, dst, w } | EdgeOp::Reweight { src, dst, w } => {
            m.insert((src, dst), w);
        }
        EdgeOp::Delete { src, dst } => {
            m.remove(&(src, dst));
        }
    }
}

fn reference_rows(m: &BTreeMap<(u32, u32), f32>) -> Vec<Vec<(u32, f32)>> {
    let mut rows = vec![Vec::new(); N];
    for (&(r, c), &w) in m {
        rows[r as usize].push((c, w));
    }
    rows
}

fn all_rows(store: &StreamStore) -> Vec<Vec<(u32, f32)>> {
    (0..N as u32).map(|r| store.read_row(r)).collect()
}

fn assert_rows_bit_identical(got: &[Vec<(u32, f32)>], want: &[Vec<(u32, f32)>], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: row count");
    for (r, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.len(), w.len(), "{ctx}: row {r} length {g:?} vs {w:?}");
        for (&(gc, gw), &(wc, ww)) in g.iter().zip(w) {
            assert_eq!(gc, wc, "{ctx}: row {r} column drift");
            assert_eq!(gw.to_bits(), ww.to_bits(), "{ctx}: row {r} col {gc} weight bits");
        }
    }
}

/// Drive `ops` through a store at `dir`, compacting every `compact_each`
/// successful ingests. Injected crashes simulate process death: the store
/// is dropped and re-opened (recovery), the ack watermark is asserted
/// monotone, and the crashed op is retried (it was never acknowledged).
/// Injected I/O errors and short writes retry the same op in place.
/// Returns the final merged rows (also verified to survive one last
/// clean reopen).
fn drive(dir: PathBuf, cfg_plan: Arc<FaultPlan>, ops: &[EdgeOp], compact_each: usize) -> Vec<Vec<(u32, f32)>> {
    let mut cfg = StreamConfig::new(dir, N);
    cfg.sync_every = 1; // every Ok(ingest) is acknowledged
    cfg.faults = cfg_plan;
    let mut store = StreamStore::open(cfg.clone()).unwrap();
    let mut done = 0usize;
    while done < ops.len() {
        match store.ingest(ops[done]) {
            Ok(_) => {
                done += 1;
                if done % compact_each == 0 {
                    match store.compact_once() {
                        Ok(_) => {}
                        Err(StreamError::Crashed { .. }) => {
                            let acked = store.acked();
                            drop(store);
                            store = StreamStore::open(cfg.clone()).unwrap();
                            assert!(
                                store.acked() >= acked,
                                "ack watermark regressed across compaction-crash recovery"
                            );
                        }
                        // Injected checkpoint-write I/O error: the frozen
                        // overlay stays merged for readers and the next
                        // boundary retries the cycle.
                        Err(StreamError::Io { .. }) => {}
                        Err(e) => panic!("unexpected compaction failure: {e}"),
                    }
                }
            }
            Err(StreamError::Crashed { .. }) => {
                let acked = store.acked();
                drop(store);
                store = StreamStore::open(cfg.clone()).unwrap();
                assert!(store.acked() >= acked, "ack watermark regressed across recovery");
                // `done` not advanced: the torn op was never acknowledged.
            }
            Err(StreamError::Io { .. }) => {
                // Short write / injected I/O error: absolute ops retry safely.
            }
            Err(e) => panic!("unexpected ingest failure: {e}"),
        }
    }
    store.flush().unwrap();
    let rows = all_rows(&store);
    // One last clean restart: the merged view must be rebuilt exactly.
    drop(store);
    let store = StreamStore::open(cfg).unwrap();
    assert_rows_bit_identical(&all_rows(&store), &rows, "post-run reopen");
    rows
}

#[test]
fn fault_free_stream_matches_the_reference_map() {
    let ops = scripted_ops(120, 0x51B);
    let mut reference = BTreeMap::new();
    for op in &ops {
        apply_reference(&mut reference, op);
    }
    let rows = drive(dir("fault_free"), Arc::new(FaultPlan::inert()), &ops, 25);
    assert_rows_bit_identical(&rows, &reference_rows(&reference), "fault-free vs reference");
}

/// The acceptance gate: every scripted crash ordinal across every
/// durability seam recovers to reads bit-identical to the fault-free run.
#[test]
fn every_crash_ordinal_recovers_bit_identically() {
    let ops = scripted_ops(40, 0xC4A5);
    let baseline = drive(dir("sweep_base"), Arc::new(FaultPlan::inert()), &ops, 10);
    // Seam decisions per fault-free run: 40 wal-appends + 4 compactions
    // × 2 seams = 48. Sweep past the end to prove over-long scripts are
    // inert (those runs must equal the baseline trivially).
    for ordinal in 1..=50u64 {
        let plan = Arc::new(FaultPlan::inert().script(FaultKind::CrashPoint, &[ordinal]));
        let rows = drive(dir(&format!("sweep_{ordinal}")), plan, &ops, 10);
        assert_rows_bit_identical(&rows, &baseline, &format!("crash ordinal {ordinal}"));
    }
}

#[test]
fn short_writes_and_io_errors_retry_to_the_same_state() {
    let ops = scripted_ops(60, 0x10E);
    let baseline = drive(dir("retry_base"), Arc::new(FaultPlan::inert()), &ops, 20);
    // Scripted failures across both lanes: short writes tear the WAL tail
    // (healed on the next append), I/O errors fail cleanly — both leave
    // the op unacknowledged and retryable.
    let plan = Arc::new(
        FaultPlan::inert()
            .script(FaultKind::ShortWrite, &[3, 17, 18, 41])
            .script(FaultKind::IoError, &[5, 17, 30]),
    );
    let rows = drive(dir("retry_faulty"), plan, &ops, 20);
    assert_rows_bit_identical(&rows, &baseline, "short-write/io-error retries");
}

#[test]
fn out_of_range_row_reads_are_empty() {
    let mut cfg = StreamConfig::new(dir("oob_read"), N);
    cfg.sync_every = 1;
    let store = StreamStore::open(cfg).unwrap();
    store.ingest(EdgeOp::Insert { src: 0, dst: 1, w: 1.0 }).unwrap();
    // Ingest rejects out-of-bounds endpoints, so no row can exist past
    // n_nodes — reading one is an empty row, not an index panic.
    assert!(store.read_row(N as u32).is_empty());
    assert!(store.read_row(u32::MAX).is_empty());
    assert_eq!(store.read_row(0), vec![(1, 1.0)]);
}

/// Regression for the ingest/freeze race: WAL-seq assignment and
/// overlay apply must be one atomic step with respect to compaction's
/// freeze. Before the fix, op k could be fsynced but not yet applied
/// while op k+1 advanced `applied_seq`; a freeze at k+1 then checkpointed
/// a master missing op k and dropped its WAL record — silently losing an
/// acknowledged write across the next restart. Concurrent ingesters race
/// a compaction-hammering thread; afterwards a clean restart must still
/// reconstruct every acknowledged op bit-identically.
#[test]
fn concurrent_ingest_and_compaction_loses_no_acknowledged_write() {
    const WRITERS: usize = 4;
    const PER_WRITER: usize = 200;
    // Disjoint edge sets per writer (N*N/WRITERS slots each): same-writer
    // re-inserts are ordered by that writer, cross-writer edges never
    // collide, so the final adjacency is interleaving-independent.
    const SLOTS: usize = N * N / WRITERS;
    fn edge(t: usize, i: usize) -> EdgeOp {
        let e = t * SLOTS + (i % SLOTS);
        EdgeOp::Insert { src: (e / N) as u32, dst: (e % N) as u32, w: (i + 1) as f32 }
    }

    let mut cfg = StreamConfig::new(dir("concurrent"), N);
    cfg.sync_every = 4; // batched acks: the window the atomicity fix closes
    cfg.compact_every = usize::MAX; // compactions driven explicitly below
    let store = Arc::new(StreamStore::open(cfg.clone()).unwrap());

    let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let compactor = {
        let store = Arc::clone(&store);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            // ord: plain stop flag; a stale read only runs one extra cycle.
            while !done.load(std::sync::atomic::Ordering::Relaxed) {
                store.compact_once().unwrap();
            }
        })
    };
    let writers: Vec<_> = (0..WRITERS)
        .map(|t| {
            let store = Arc::clone(&store);
            std::thread::spawn(move || {
                for i in 0..PER_WRITER {
                    store.ingest(edge(t, i)).unwrap();
                }
            })
        })
        .collect();
    for h in writers {
        h.join().unwrap();
    }
    // ord: plain stop flag, see above.
    done.store(true, std::sync::atomic::Ordering::Relaxed);
    compactor.join().unwrap();

    store.flush().unwrap();
    assert_eq!(store.acked(), (WRITERS * PER_WRITER) as u64);
    let mut reference = BTreeMap::new();
    for t in 0..WRITERS {
        for i in 0..PER_WRITER {
            apply_reference(&mut reference, &edge(t, i));
        }
    }
    let want = reference_rows(&reference);
    assert_rows_bit_identical(&all_rows(&store), &want, "pre-restart merged reads");

    // The actual gate: recovery after a clean shutdown (checkpoint + WAL
    // tail) still holds every acknowledged write.
    let store = Arc::try_unwrap(store).ok().expect("all threads joined");
    drop(store);
    let store = StreamStore::open(cfg).unwrap();
    assert_eq!(store.acked(), (WRITERS * PER_WRITER) as u64, "ack watermark survives restart");
    assert_rows_bit_identical(&all_rows(&store), &want, "post-restart merged reads");
}

#[test]
fn compaction_normalizes_rows_and_bumps_the_published_epoch() {
    let mut cfg = StreamConfig::new(dir("norm"), N);
    cfg.sync_every = 1;
    let store = StreamStore::open(cfg).unwrap();
    assert_eq!(store.published().version, 0);
    store.ingest(EdgeOp::Insert { src: 2, dst: 0, w: 1.0 }).unwrap();
    store.ingest(EdgeOp::Insert { src: 2, dst: 7, w: 3.0 }).unwrap();
    store.compact_once().unwrap();
    let snap = store.published();
    assert_eq!(snap.version, 1);
    assert_eq!(snap.seq, 2);
    // Row-stochastic: published norm rows sum to 1.
    let norm_row: Vec<(usize, f32)> = match &*snap.norm {
        gnn_spmm::sparse::SparseMatrix::Csr(c) => c.row_entries(2).collect(),
        other => panic!("stream masters are CSR, found {:?}", other.format()),
    };
    let sum: f32 = norm_row.iter().map(|&(_, w)| w).sum();
    assert!((sum - 1.0).abs() < 1e-6, "row 2 norm sums to {sum}");
    assert_eq!(norm_row[0].0, 0);
    assert_eq!(norm_row[1].0, 7);
    assert!(norm_row[1].1 > norm_row[0].1, "weights keep their ratio");
}

#[test]
fn compactor_crash_loop_degrades_ingest_but_reads_stay_live() {
    let mut cfg = StreamConfig::new(dir("degraded"), N);
    cfg.sync_every = 1;
    cfg.compact_every = 4;
    cfg.restart_budget = 1;
    // Every supervised cycle panics at the maybe_panic seam: attempt 1
    // spends the budget, attempt 2 exceeds it → degraded.
    cfg.faults = Arc::new(FaultPlan::inert().script(FaultKind::Panic, &[1, 2]));
    let mut store = StreamStore::open(cfg).unwrap();
    store.spawn_compactor();
    for i in 0..4u32 {
        store.ingest(EdgeOp::Insert { src: i, dst: i + 1, w: 1.0 }).unwrap();
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    while !store.degraded() {
        assert!(Instant::now() < deadline, "compactor never degraded");
        std::thread::sleep(Duration::from_millis(10));
    }
    let err = store.ingest(EdgeOp::Insert { src: 9, dst: 9, w: 1.0 }).unwrap_err();
    assert_eq!(err.kind(), "backpressure");
    assert!(matches!(err, StreamError::Backpressure { pending } if pending >= 4));
    // Reads keep serving: the merged row path and the published snapshot
    // both stay live on the pre-degradation state.
    assert_eq!(store.read_row(0), vec![(1, 1.0)]);
    assert_eq!(store.published().version, 0);
    let stats = store.stats();
    assert!(stats.degraded);
    assert_eq!(stats.compactor_restarts, 2);
    assert_eq!(stats.acked, 4, "acknowledged writes are untouched by degradation");
}

#[test]
fn serve_publishes_the_streamed_epoch() {
    let spec = DatasetSpec {
        name: "StreamServe",
        n: N,
        feat_dim: 8,
        adj_density: 0.2,
        feat_density: 0.4,
        n_classes: 3,
    };
    let ds = Arc::new(GraphDataset::generate(&spec, &mut Rng::new(7)));
    let template = Arc::new(train_template(ModelKind::Gcn, &ds, 8, 0.02, 2, 1));
    let cfg = ServeConfig { workers: 1, queue_capacity: 8, hidden: 8, ..Default::default() };
    let srv = InferenceServer::start(
        cfg,
        Arc::clone(&ds),
        template,
        EngineSnapshot::from_dataset(&ds, 0),
        None,
    );

    let mut scfg = StreamConfig::new(dir("serve"), N);
    scfg.sync_every = 1;
    let store = StreamStore::open(scfg).unwrap();
    for i in 0..N as u32 {
        store.ingest(EdgeOp::Insert { src: i, dst: (i + 1) % N as u32, w: 1.0 }).unwrap();
    }
    store.compact_once().unwrap();

    let feats = srv.current_snapshot().feats.clone();
    srv.publish_from_stream(&store, feats).unwrap();
    let snap = srv.current_snapshot();
    assert_eq!(snap.version, store.published().version);
    assert_eq!(snap.n_nodes(), N);
    // Requests run against the streamed adjacency.
    srv.submit(vec![0, 1, 2]).unwrap();
    let responses = srv.drain();
    assert_eq!(responses.len(), 1);
    assert!(responses[0].result.is_ok(), "{:?}", responses[0].result.as_ref().err());
    srv.shutdown();
}

#[test]
fn compaction_publish_forces_the_engine_to_redecide() {
    let mut cfg = StreamConfig::new(dir("redecide"), N);
    cfg.sync_every = 1;
    let store = StreamStore::open(cfg).unwrap();
    for i in 0..N as u32 {
        store.ingest(EdgeOp::Insert { src: i, dst: (i + 1) % N as u32, w: 1.0 }).unwrap();
    }
    store.compact_once().unwrap();

    let mut policy = StaticPolicy(Format::Csr);
    let mut engine = AdjEngine::new(&mut policy);
    let slot = engine.add_slot_shared("stream-adj", store.published().norm.clone());
    let x = gnn_spmm::tensor::Matrix::rand(N, 4, &mut Rng::new(3));
    let _ = engine.spmm(slot, &x);
    assert_eq!(engine.decisions.len(), 1, "first bind decides");

    // Rebinding the *same* published epoch is an identity no-op…
    engine.set_slot_matrix(slot, store.published().norm.clone());
    let _ = engine.spmm(slot, &x);
    assert_eq!(engine.decisions.len(), 1, "same-epoch rebind must not re-decide");

    // …but a compaction publishes a fresh master identity, so the rebind
    // re-decides (the predictor's drift anchors see a new matrix).
    store.ingest(EdgeOp::Insert { src: 0, dst: N as u32 - 1, w: 2.0 }).unwrap();
    store.compact_once().unwrap();
    engine.set_slot_matrix(slot, store.published().norm.clone());
    let _ = engine.spmm(slot, &x);
    assert_eq!(engine.decisions.len(), 2, "new epoch identity forces a re-decision");
}
