//! Serving-layer integration: epoch-swap snapshot isolation under
//! concurrent load (DESIGN.md §Serving).
//!
//! The stress test drives N worker threads against M mid-stream epoch
//! swaps and then *replays every request serially* against whichever
//! snapshot the worker observed — logits must be **bit-identical**
//! (`StaticPolicy(Csr)` keeps every kernel on the row-independent gather
//! path, so parallel pool splits cannot reorder the accumulation). The
//! refcount checks reuse the `integration_shared.rs` flatness idiom:
//! displaced snapshots must drop to exactly the handles the test holds,
//! and must free entirely once those are gone.

use gnn_spmm::gnn::engine::StaticPolicy;
use gnn_spmm::gnn::{AdjEngine, ModelKind};
use gnn_spmm::graph::{DatasetSpec, GraphDataset};
use gnn_spmm::serve::{
    train_template, EngineSnapshot, InferenceServer, ServeConfig, ServedModel,
};
use gnn_spmm::sparse::Format;
use gnn_spmm::tensor::Matrix;
use gnn_spmm::util::rng::Rng;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

const N: usize = 150;
const HIDDEN: usize = 16;

fn spec() -> DatasetSpec {
    DatasetSpec {
        name: "ServeStress",
        n: N,
        feat_dim: 24,
        adj_density: 0.05,
        feat_density: 0.2,
        n_classes: 4,
    }
}

/// Same shape, different structure per seed: every snapshot variant is a
/// *content* change (logits must differ), while the template's weight
/// dimensions stay valid across all of them.
fn variant(seed: u64) -> GraphDataset {
    GraphDataset::generate(&spec(), &mut Rng::new(seed))
}

fn serial_replay(
    template: &ServedModel,
    ds: &GraphDataset,
    snap: &EngineSnapshot,
    nodes: &[u32],
) -> Matrix {
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    let mut rng = Rng::new(0x5E71A);
    let mut replica = template.replicate(ds, HIDDEN, 0.02, &mut rng, &mut eng);
    let all_cols: Vec<u32> = (0..ds.features.cols as u32).collect();
    let x = snap.feats.extract_rows_cols(nodes, &all_cols);
    let a = snap.adjn.extract_rows_cols(nodes, nodes);
    replica.set_graph(&mut eng, x, a);
    replica.forward(&mut eng)
}

#[test]
fn stress_swaps_never_corrupt_in_flight_requests() {
    let ds = Arc::new(variant(1));
    let template = Arc::new(train_template(ModelKind::Gcn, &ds, HIDDEN, 0.02, 5, 2));
    // M snapshot variants published mid-stream (version = index + 1; the
    // boot snapshot is version 0).
    let snaps: Vec<Arc<EngineSnapshot>> = (0..4)
        .map(|i| Arc::new(EngineSnapshot::from_dataset(&variant(100 + i as u64), i as u64 + 1)))
        .collect();
    let cfg = ServeConfig { workers: 4, queue_capacity: 32, hidden: HIDDEN, ..Default::default() };
    let srv = InferenceServer::start(
        cfg,
        Arc::clone(&ds),
        Arc::clone(&template),
        EngineSnapshot::from_dataset(&ds, 0),
        None,
    );
    let snap0 = srv.current_snapshot();

    // Round 1: before any swap — every response must observe version 0.
    let mut rng = Rng::new(0xFEED);
    let batch = |srv: &InferenceServer, rng: &mut Rng, n: usize| {
        for _ in 0..n {
            let k = 4 + (rng.next_u64() % 9) as usize;
            let nodes: Vec<u32> = (0..k).map(|_| (rng.next_u64() % N as u64) as u32).collect();
            srv.submit(nodes).unwrap();
        }
    };
    batch(&srv, &mut rng, 10);
    let mut responses = srv.drain();
    let ver = |r: &gnn_spmm::serve::InferenceResponse| r.ok().expect("request served").snapshot_version;
    assert!(responses.iter().all(|r| ver(r) == 0));

    // Round 2: writer swaps concurrently with the request stream; requests
    // keep completing throughout (a blocked reader would deadlock the
    // drain — the queue backlog guarantees swaps land mid-request).
    std::thread::scope(|s| {
        s.spawn(|| {
            for snap in &snaps {
                std::thread::sleep(Duration::from_millis(2));
                srv.publish_arc(Arc::clone(snap)).unwrap();
            }
        });
        batch(&srv, &mut rng, 80);
    });
    responses.extend(srv.drain());
    assert_eq!(srv.snapshot_epoch(), snaps.len() as u64, "every publish landed");

    // Round 3: after every swap — only the final version is served.
    batch(&srv, &mut rng, 10);
    let last_round = srv.drain();
    assert!(last_round.iter().all(|r| ver(r) == snaps.len() as u64));
    responses.extend(last_round);
    assert_eq!(responses.len(), 100);

    // (a) Bit-identical serial replay against the observed snapshot.
    let versions: HashSet<u64> = responses.iter().map(&ver).collect();
    assert!(versions.len() >= 2, "stream saw only versions {versions:?}");
    for r in &responses {
        let inf = r.ok().expect("request served");
        let snap: &EngineSnapshot = if inf.snapshot_version == 0 {
            &snap0
        } else {
            &snaps[(inf.snapshot_version - 1) as usize]
        };
        let want = serial_replay(&template, &ds, snap, &r.nodes);
        assert_eq!(
            inf.logits.data, want.data,
            "request {} (snapshot v{}) diverged from serial replay",
            r.id, inf.snapshot_version
        );
    }

    // (b) No refcount leaks after drain: every displaced snapshot is down
    // to the handles this test holds — EngineSnapshot Arcs…
    for snap in snaps.iter().take(snaps.len() - 1) {
        assert_eq!(
            Arc::strong_count(snap),
            1,
            "displaced snapshot v{} still co-owned",
            snap.version
        );
        // …and their matrix payloads (one handle each, the snapshot's own).
        assert_eq!(snap.feats.strong_count(), 1);
        assert_eq!(snap.adjn.strong_count(), 1);
    }
    // The current snapshot is co-owned by exactly the cell and us.
    let last = snaps.last().unwrap();
    assert_eq!(Arc::strong_count(last), 2, "current snapshot: cell + test");
    drop(snap0);

    // Shutdown releases the cell's handle; the final snapshot then frees
    // with our last drop (observed through a weak token).
    let weak_last = Arc::downgrade(last);
    srv.shutdown();
    drop(snaps);
    assert!(weak_last.upgrade().is_none(), "snapshot leaked past all owners");
}

#[test]
fn snapshot_content_actually_changes_results() {
    // Guard for the stress test's power: two snapshot versions must give
    // different logits for the same node batch, otherwise "bit-identical
    // replay" would pass vacuously.
    let ds = variant(1);
    let template = train_template(ModelKind::Gcn, &ds, HIDDEN, 0.02, 5, 2);
    let nodes: Vec<u32> = (0..12).collect();
    let a = serial_replay(&template, &ds, &EngineSnapshot::from_dataset(&ds, 0), &nodes);
    let b = serial_replay(
        &template,
        &ds,
        &EngineSnapshot::from_dataset(&variant(100), 1),
        &nodes,
    );
    assert_ne!(a.data, b.data, "snapshot variants must be distinguishable");
}

#[test]
fn workers_share_one_warm_cache_lock_free() {
    // Every worker consults the same warm cache; its atomic counters see
    // traffic from all of them, and shared mode never grows the cache
    // (read-only by construction).
    let ds = Arc::new(variant(7));
    let template = Arc::new(train_template(ModelKind::Egc, &ds, HIDDEN, 0.02, 4, 3));
    let cfg = ServeConfig { workers: 3, queue_capacity: 16, hidden: HIDDEN, ..Default::default() };
    let srv = InferenceServer::start(
        cfg,
        Arc::clone(&ds),
        template,
        EngineSnapshot::from_dataset(&ds, 0),
        Some(gnn_spmm::predictor::DecisionCache::new(0.5)),
    );
    let entries_before = 0; // fresh cache
    for i in 0..30u32 {
        srv.submit(vec![i, i + 1, i + 2, i + 3, i + 4, i + 5]).unwrap();
    }
    srv.drain();
    let stats = srv.cache_stats();
    assert!(
        stats.hits + stats.misses > 0,
        "workers never consulted the shared cache"
    );
    assert_eq!(
        stats.entries, entries_before,
        "a shared cache must stay read-only (no stores from serving)"
    );
    srv.shutdown();
}
