//! §Shared-Ownership integration: the Arc-backed rebind machinery across
//! all five models.
//!
//! * **Rebind equivalence** — flipping onto the dedicated eval slots
//!   (handle-bound masters) produces *bit-identical* logits to the legacy
//!   deep-clone rebind path (`set_graph` with deep copies of the masters)
//!   for every model. Same content, same decided format, same
//!   deterministic row-parallel CSR kernels ⇒ the comparison is exact
//!   (`max_abs_diff == 0.0`), not approximate.
//! * **Refcount flatness** — after N epochs of shard rebinds + eval flips,
//!   the masters' `Arc` strong counts sit exactly where they were after
//!   the initial bind: nothing duplicates them, nothing leaks handles.

use gnn_spmm::gnn::egc::Egc;
use gnn_spmm::gnn::engine::{AdjEngine, StaticPolicy};
use gnn_spmm::gnn::film::Film;
use gnn_spmm::gnn::gat::Gat;
use gnn_spmm::gnn::gcn::Gcn;
use gnn_spmm::gnn::rgcn::{relation_operands, Rgcn};
use gnn_spmm::graph::{DatasetSpec, GraphDataset};
use gnn_spmm::sparse::{Coo, Csr, Format, SharedMatrix};
use gnn_spmm::tensor::{ops, Matrix};
use gnn_spmm::util::rng::Rng;
use std::sync::Arc;

fn small() -> GraphDataset {
    let mut rng = Rng::new(0x5AEB);
    GraphDataset::generate(
        &DatasetSpec {
            name: "SharedSmall",
            n: 300,
            feat_dim: 24,
            adj_density: 0.03,
            feat_density: 0.15,
            n_classes: 4,
        },
        &mut rng,
    )
}

/// CSR masters shared between the eval binding and the deep-clone
/// reference (the same operands the mini-batch driver builds).
struct Masters {
    feats: SharedMatrix,
    adjn: SharedMatrix,
    rels: Vec<SharedMatrix>,
    pattern: Arc<Coo>,
}

fn masters(ds: &GraphDataset) -> Masters {
    Masters {
        feats: SharedMatrix::from(Csr::from_coo(&ds.features)),
        adjn: SharedMatrix::from(Csr::from_coo(&ds.adj_norm)),
        rels: relation_operands(&ds.adj)
            .iter()
            .map(|r| SharedMatrix::from(Csr::from_coo(r)))
            .collect(),
        pattern: Arc::new(Gat::attention_pattern(&ds.adj)),
    }
}

/// A plausible shard selection plus the full feature-column identity.
fn shard_of(ds: &GraphDataset) -> (Vec<u32>, Vec<u32>) {
    let shard: Vec<u32> = (0..ds.adj.rows as u32).step_by(3).collect();
    let cols: Vec<u32> = (0..ds.features.cols as u32).collect();
    (shard, cols)
}

/// Drive one model into a realistic mid-run state (two shard-train
/// steps), then return logits from (a) the eval-slot flip and (b) a
/// deep-clone rebind executed right after it. `$shard` rebinds the train
/// slots to an induced subgraph; `$deep` rebinds them to deep copies of
/// the full masters.
macro_rules! flip_vs_deep {
    ($model:ident, $eng:ident, $ds:ident, shard: $shard:expr, deep: $deep:expr) => {{
        for _ in 0..2 {
            $shard;
            let logits = $model.forward(&mut $eng);
            let n = logits.rows;
            let mask = vec![true; n];
            // Positionally sliced labels: semantically arbitrary for a
            // shard, but deterministic — this test compares numerics of
            // two rebind paths, not learning quality.
            let (_, dlogits) =
                ops::masked_xent_with_grad(&logits, &$ds.labels[..n], &mask);
            let g = $model.backward_grads(&mut $eng, &dlogits);
            $model.apply_grads(&g);
        }
        $model.use_eval_graph();
        let flip: Matrix = $model.forward(&mut $eng);
        $deep;
        let deep: Matrix = $model.forward(&mut $eng);
        (flip, deep)
    }};
}

#[test]
fn gcn_eval_flip_is_bit_identical_to_deep_clone_rebind() {
    let ds = small();
    let m = masters(&ds);
    let (shard, cols) = shard_of(&ds);
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    let mut rng = Rng::new(7);
    let mut model = Gcn::new(&ds, 8, 0.02, &mut rng, &mut eng);
    model.bind_eval_graph(&mut eng, m.feats.clone(), m.adjn.clone());
    let (flip, deep) = flip_vs_deep!(model, eng, ds,
        shard: model.set_graph(
            &mut eng,
            m.feats.extract_rows_cols(&shard, &cols),
            m.adjn.extract_rows_cols(&shard, &shard),
        ),
        deep: model.set_graph(&mut eng, (*m.feats).clone(), (*m.adjn).clone())
    );
    assert_eq!(flip.shape(), deep.shape());
    assert_eq!(
        flip.max_abs_diff(&deep),
        0.0,
        "shared-handle eval flip must be bit-identical to the deep-clone rebind"
    );
}

#[test]
fn film_eval_flip_is_bit_identical_to_deep_clone_rebind() {
    let ds = small();
    let m = masters(&ds);
    let (shard, cols) = shard_of(&ds);
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    let mut rng = Rng::new(8);
    let mut model = Film::new(&ds, 8, 0.02, &mut rng, &mut eng);
    model.bind_eval_graph(&mut eng, m.feats.clone(), m.adjn.clone());
    let (flip, deep) = flip_vs_deep!(model, eng, ds,
        shard: model.set_graph(
            &mut eng,
            m.feats.extract_rows_cols(&shard, &cols),
            m.adjn.extract_rows_cols(&shard, &shard),
        ),
        deep: model.set_graph(&mut eng, (*m.feats).clone(), (*m.adjn).clone())
    );
    assert_eq!(flip.max_abs_diff(&deep), 0.0, "FiLM flip ≠ deep-clone rebind");
}

#[test]
fn egc_eval_flip_is_bit_identical_to_deep_clone_rebind() {
    let ds = small();
    let m = masters(&ds);
    let (shard, cols) = shard_of(&ds);
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    let mut rng = Rng::new(9);
    let mut model = Egc::new(&ds, 8, 0.02, &mut rng, &mut eng);
    model.bind_eval_graph(&mut eng, m.feats.clone(), m.adjn.clone());
    let (flip, deep) = flip_vs_deep!(model, eng, ds,
        shard: model.set_graph(
            &mut eng,
            m.feats.extract_rows_cols(&shard, &cols),
            m.adjn.extract_rows_cols(&shard, &shard),
        ),
        deep: model.set_graph(&mut eng, (*m.feats).clone(), (*m.adjn).clone())
    );
    assert_eq!(flip.max_abs_diff(&deep), 0.0, "EGC flip ≠ deep-clone rebind");
}

#[test]
fn gat_eval_flip_is_bit_identical_to_deep_clone_rebind() {
    let ds = small();
    let m = masters(&ds);
    let (shard, cols) = shard_of(&ds);
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    let mut rng = Rng::new(10);
    let mut model = Gat::new(&ds, 8, 0.02, &mut rng, &mut eng);
    model.bind_eval_graph(&mut eng, m.feats.clone(), m.pattern.clone());
    let (flip, deep) = flip_vs_deep!(model, eng, ds,
        shard: model.set_graph(
            &mut eng,
            m.feats.extract_rows_cols(&shard, &cols),
            Gat::attention_pattern(&ds.adj.extract_rows_cols(&shard, &shard)),
        ),
        deep: model.set_graph(&mut eng, (*m.feats).clone(), (*m.pattern).clone())
    );
    assert_eq!(flip.max_abs_diff(&deep), 0.0, "GAT flip ≠ deep-clone rebind");
}

#[test]
fn rgcn_eval_flip_is_bit_identical_to_deep_clone_rebind() {
    let ds = small();
    let m = masters(&ds);
    let (shard, cols) = shard_of(&ds);
    let rels = relation_operands(&ds.adj);
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    let mut rng = Rng::new(11);
    let mut model = Rgcn::with_relations(&ds, &rels, 8, 0.02, &mut rng, &mut eng);
    model.bind_eval_graph(&mut eng, m.feats.clone(), m.rels.clone());
    let (flip, deep) = flip_vs_deep!(model, eng, ds,
        shard: model.set_graph(
            &mut eng,
            m.feats.extract_rows_cols(&shard, &cols),
            m.rels
                .iter()
                .map(|r| SharedMatrix::from(r.extract_rows_cols(&shard, &shard)))
                .collect(),
        ),
        deep: model.set_graph(
            &mut eng,
            (*m.feats).clone(),
            m.rels.iter().map(|r| SharedMatrix::from((**r).clone())).collect(),
        )
    );
    assert_eq!(flip.max_abs_diff(&deep), 0.0, "RGCN flip ≠ deep-clone rebind");
}

/// The masters are never duplicated: strong counts after N epochs of
/// shard-bind + eval-flip cycles equal the counts right after the initial
/// eval bind settles. (CSR masters + a CSR policy ⇒ the eval slots keep
/// the very master handles; nothing converts, nothing copies.)
#[test]
fn master_refcounts_stay_flat_across_epochs() {
    let ds = small();
    let m = masters(&ds);
    let (shard, cols) = shard_of(&ds);
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    let mut rng = Rng::new(12);
    let mut model = Gcn::new(&ds, 8, 0.02, &mut rng, &mut eng);
    model.bind_eval_graph(&mut eng, m.feats.clone(), m.adjn.clone());
    // One full eval so decisions (and any conversions — none expected for
    // CSR-on-CSR) settle before the counts are anchored.
    model.use_eval_graph();
    let _ = model.forward(&mut eng);
    let feats_count = m.feats.strong_count();
    let adjn_count = m.adjn.strong_count();
    for _ in 0..6 {
        model.set_graph(
            &mut eng,
            m.feats.extract_rows_cols(&shard, &cols),
            m.adjn.extract_rows_cols(&shard, &shard),
        );
        let logits = model.forward(&mut eng);
        let n = logits.rows;
        let mask = vec![true; n];
        let (_, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels[..n], &mask);
        let g = model.backward_grads(&mut eng, &dlogits);
        model.apply_grads(&g);
        model.use_eval_graph();
        let _ = model.forward(&mut eng);
        assert_eq!(m.feats.strong_count(), feats_count, "features master duplicated");
        assert_eq!(m.adjn.strong_count(), adjn_count, "adjacency master duplicated");
    }

    // RGCN: the R relation masters stay flat too (the old eval path cloned
    // each one ~2× per epoch).
    let rels = relation_operands(&ds.adj);
    let mut policy2 = StaticPolicy(Format::Csr);
    let mut eng2 = AdjEngine::new(&mut policy2);
    let mut rng2 = Rng::new(13);
    let mut rgcn = Rgcn::with_relations(&ds, &rels, 8, 0.02, &mut rng2, &mut eng2);
    rgcn.bind_eval_graph(&mut eng2, m.feats.clone(), m.rels.clone());
    rgcn.use_eval_graph();
    let _ = rgcn.forward(&mut eng2);
    let rel_counts: Vec<usize> = m.rels.iter().map(|r| r.strong_count()).collect();
    for _ in 0..4 {
        rgcn.set_graph(
            &mut eng2,
            m.feats.extract_rows_cols(&shard, &cols),
            m.rels
                .iter()
                .map(|r| SharedMatrix::from(r.extract_rows_cols(&shard, &shard)))
                .collect(),
        );
        let _ = rgcn.forward(&mut eng2);
        rgcn.use_eval_graph();
        let _ = rgcn.forward(&mut eng2);
        for (r, want) in m.rels.iter().zip(&rel_counts) {
            assert_eq!(r.strong_count(), *want, "relation master duplicated");
        }
    }
}
