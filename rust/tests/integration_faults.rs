//! Fault-injection integration suite (DESIGN.md §Fault-Tolerance).
//!
//! Drives the serving stack through the deterministic `testing::fault`
//! harness and checks the liveness contract end to end: **every submitted
//! request gets exactly one response** (logits or a typed error), worker
//! panics are paid for by exactly one request each and answered by a
//! supervisor respawn, restarted workers serve **bit-identical** logits
//! (fresh replica, same template weights), admission control sheds and
//! expires deterministically, snapshot publication rejects a crafted
//! malformed instance of every one of the seven formats, and `drain`
//! terminates even when the restart budget burns out with requests still
//! queued.

use gnn_spmm::gnn::engine::StaticPolicy;
use gnn_spmm::gnn::{AdjEngine, ModelKind};
use gnn_spmm::graph::{DatasetSpec, GraphDataset};
use gnn_spmm::serve::{
    train_template, EngineSnapshot, InferenceServer, ServeConfig, ServeError, ServedModel,
};
use gnn_spmm::sparse::{Format, SharedMatrix, SparseMatrix, ALL_FORMATS};
use gnn_spmm::tensor::Matrix;
use gnn_spmm::testing::{FaultKind, FaultPlan};
use gnn_spmm::util::rng::Rng;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

const N: usize = 120;
const HIDDEN: usize = 16;

fn spec() -> DatasetSpec {
    DatasetSpec {
        name: "FaultStress",
        n: N,
        feat_dim: 20,
        adj_density: 0.05,
        feat_density: 0.2,
        n_classes: 4,
    }
}

fn variant(seed: u64) -> GraphDataset {
    GraphDataset::generate(&spec(), &mut Rng::new(seed))
}

fn serial_replay(
    template: &ServedModel,
    ds: &GraphDataset,
    snap: &EngineSnapshot,
    nodes: &[u32],
) -> Matrix {
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    let mut rng = Rng::new(0xFA_17);
    let mut replica = template.replicate(ds, HIDDEN, 0.02, &mut rng, &mut eng);
    let all_cols: Vec<u32> = (0..ds.features.cols as u32).collect();
    let x = snap.feats.extract_rows_cols(nodes, &all_cols);
    let a = snap.adjn.extract_rows_cols(nodes, nodes);
    replica.set_graph(&mut eng, x, a);
    replica.forward(&mut eng)
}

/// The tentpole liveness test: scripted worker panics land mid-stream
/// while a writer publishes snapshot swaps concurrently. Exactly one
/// response per submission, every panic answered and respawned, every
/// successful response bit-identical to a serial replay against the
/// snapshot it observed, and no snapshot refcount leaks afterwards.
#[test]
fn scripted_panics_under_concurrent_swaps_keep_every_request_answered() {
    let ds = Arc::new(variant(1));
    let template = Arc::new(train_template(ModelKind::Gcn, &ds, HIDDEN, 0.02, 5, 2));
    let snaps: Vec<Arc<EngineSnapshot>> = (0..3)
        .map(|i| Arc::new(EngineSnapshot::from_dataset(&variant(200 + i as u64), i as u64 + 1)))
        .collect();
    // Ordinals count inference attempts across all workers (the plan is
    // shared through the config's Arc), so these three panics land at
    // deterministic points of the request stream regardless of which
    // worker draws them.
    let scripted: &[u64] = &[7, 23, 41];
    let cfg = ServeConfig {
        workers: 3,
        queue_capacity: 32,
        hidden: HIDDEN,
        restart_budget: 8,
        faults: Arc::new(FaultPlan::inert().script(FaultKind::Panic, scripted)),
        ..Default::default()
    };
    let faults = Arc::clone(&cfg.faults);
    let srv = InferenceServer::start(
        cfg,
        Arc::clone(&ds),
        Arc::clone(&template),
        EngineSnapshot::from_dataset(&ds, 0),
        None,
    );
    let snap0 = srv.current_snapshot();

    let total = 60u64;
    let mut rng = Rng::new(0xFEED);
    std::thread::scope(|s| {
        s.spawn(|| {
            for snap in &snaps {
                std::thread::sleep(Duration::from_millis(2));
                srv.publish_arc(Arc::clone(snap)).unwrap();
            }
        });
        for _ in 0..total {
            let k = 4 + (rng.next_u64() % 9) as usize;
            let nodes: Vec<u32> = (0..k).map(|_| (rng.next_u64() % N as u64) as u32).collect();
            srv.submit(nodes).unwrap();
        }
    });
    let responses = srv.drain(); // must terminate despite the panics

    // Exactly one response per submission, ids 0..total each once.
    assert_eq!(responses.len(), total as usize);
    let ids: HashSet<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), total as usize, "duplicate response ids");
    assert!(ids.iter().all(|&id| id < total));

    // Every scripted panic fired, was typed, and was respawned.
    assert_eq!(faults.fired(FaultKind::Panic), scripted.len() as u64);
    let panicked: Vec<_> = responses
        .iter()
        .filter(|r| matches!(r.err(), Some(ServeError::WorkerPanic { .. })))
        .collect();
    assert_eq!(panicked.len(), scripted.len(), "one failed request per scripted panic");
    let rep = srv.report("FaultStress");
    assert_eq!(rep.panics, scripted.len() as u64);
    assert_eq!(rep.restarts, scripted.len() as u64, "every panic was respawned");
    assert!(!rep.degraded);
    assert_eq!(rep.requests, total - scripted.len() as u64, "histogram counts successes only");

    // Bit-identical replay — including responses served *after* the
    // respawns, which proves a restarted worker's fresh replica computes
    // exactly what the original would have.
    for r in &responses {
        let Some(inf) = r.ok() else { continue };
        let snap: &EngineSnapshot = if inf.snapshot_version == 0 {
            &snap0
        } else {
            &snaps[(inf.snapshot_version - 1) as usize]
        };
        let want = serial_replay(&template, &ds, snap, &r.nodes);
        assert_eq!(
            inf.logits.data, want.data,
            "request {} (snapshot v{}) diverged from serial replay",
            r.id, inf.snapshot_version
        );
    }

    // Refcounts stay flat through panics and respawns: displaced snapshots
    // are down to this test's own handle, the current one to cell + test.
    assert_eq!(srv.snapshot_epoch(), snaps.len() as u64);
    for snap in snaps.iter().take(snaps.len() - 1) {
        assert_eq!(Arc::strong_count(snap), 1, "displaced snapshot v{} leaked", snap.version);
        assert_eq!(snap.feats.strong_count(), 1);
        assert_eq!(snap.adjn.strong_count(), 1);
    }
    assert_eq!(Arc::strong_count(snaps.last().unwrap()), 2);
    drop(snap0);

    let weak_last = Arc::downgrade(snaps.last().unwrap());
    assert!(srv.shutdown().is_empty(), "drain already took every response");
    drop(snaps);
    assert!(weak_last.upgrade().is_none(), "snapshot leaked past all owners");
}

/// Admission control: a saturated queue sheds `try_submit` callers with
/// `QueueFull`, an expired deadline is dropped at dequeue without doing
/// the inference, and both show up in the report.
#[test]
fn saturated_queue_sheds_and_expired_deadlines_drop() {
    let ds = Arc::new(variant(5));
    let template = Arc::new(train_template(ModelKind::Gcn, &ds, HIDDEN, 0.02, 4, 3));
    let cfg = ServeConfig {
        workers: 1,
        queue_capacity: 1,
        hidden: HIDDEN,
        // Every served request stalls 150ms, pinning the queue full while
        // the shed/expiry probes run.
        faults: Arc::new(
            FaultPlan::inert()
                .with_rate(FaultKind::Delay, 1.0)
                .with_delay(Duration::from_millis(150)),
        ),
        ..Default::default()
    };
    let srv = InferenceServer::start(
        cfg,
        Arc::clone(&ds),
        template,
        EngineSnapshot::from_dataset(&ds, 0),
        None,
    );
    // A: picked up by the worker (then stalls 150ms). B: sits in the
    // single queue slot for at least that long.
    let a = srv.submit(vec![0, 1, 2]).unwrap();
    let b = srv.submit(vec![3, 4, 5]).unwrap();
    // C: non-blocking admission against a full queue — must shed now.
    match srv.try_submit(vec![6, 7, 8], None) {
        Err(ServeError::QueueFull) => {}
        other => panic!("expected QueueFull shed, got {other:?}"),
    }
    // D: already-expired deadline; the worker must drop it at dequeue.
    let d = srv.submit_with_deadline(vec![9, 10, 11], Some(Instant::now())).unwrap();

    let responses = srv.drain();
    assert_eq!(responses.len(), 3, "A, B, D — the shed C was never admitted");
    let by_id = |id: u64| responses.iter().find(|r| r.id == id).unwrap();
    assert!(by_id(a).is_ok());
    assert!(by_id(b).is_ok());
    assert_eq!(by_id(d).err(), Some(&ServeError::DeadlineExceeded));

    let rep = srv.report("FaultStress");
    assert_eq!(rep.shed, 1);
    assert_eq!(rep.expired, 1);
    assert_eq!(rep.requests, 2, "only A and B entered the latency histogram");
    srv.shutdown();
}

/// The snapshot-publish trust boundary, exercised for **all seven
/// formats**: a harness-corrupted adjacency in each format is refused
/// with `InvalidSnapshot`, the previous snapshot stays current, and the
/// server keeps serving afterwards.
#[test]
fn publish_rejects_a_malformed_snapshot_in_every_format() {
    let ds = Arc::new(variant(9));
    let template = Arc::new(train_template(ModelKind::Gcn, &ds, HIDDEN, 0.02, 4, 3));
    let srv = InferenceServer::start(
        ServeConfig { workers: 1, hidden: HIDDEN, ..Default::default() },
        Arc::clone(&ds),
        template,
        EngineSnapshot::from_dataset(&ds, 0),
        None,
    );
    let corruptor = FaultPlan::inert().with_rate(FaultKind::CorruptOperand, 1.0);
    let feats = SharedMatrix::from(gnn_spmm::sparse::Csr::from_coo(&ds.features));
    for (i, &fmt) in ALL_FORMATS.iter().enumerate() {
        let mut adjn = SparseMatrix::from_coo(ds.adj_norm.clone()).convert(fmt).unwrap();
        assert!(corruptor.maybe_corrupt(&mut adjn), "harness must fire at rate 1.0");
        let bad = EngineSnapshot::new(feats.clone(), SharedMatrix::new(adjn), i as u64 + 1);
        let before = srv.snapshot_epoch();
        match srv.publish_arc(Arc::new(bad)) {
            Err(ServeError::InvalidSnapshot(e)) => {
                assert_eq!(e.format, fmt, "rejection diagnosed the corrupted format");
            }
            other => panic!("{fmt:?}: expected InvalidSnapshot, got {other:?}"),
        }
        assert_eq!(srv.snapshot_epoch(), before, "{fmt:?}: epoch must not advance");
    }
    // The boot snapshot survived all seven rejections.
    srv.submit(vec![0, 1, 2, 3]).unwrap();
    let r = srv.drain();
    assert_eq!(r[0].ok().unwrap().snapshot_version, 0);
    srv.shutdown();
}

/// Restart-budget exhaustion under a crash loop: the server degrades to
/// typed rejection instead of respawn-thrashing, already-queued requests
/// are failed with typed errors, and `drain` terminates.
#[test]
fn crash_loop_degrades_and_drain_terminates() {
    let ds = Arc::new(variant(13));
    let template = Arc::new(train_template(ModelKind::Gcn, &ds, HIDDEN, 0.02, 4, 3));
    let budget = 2usize;
    let cfg = ServeConfig {
        workers: 2,
        queue_capacity: 16,
        hidden: HIDDEN,
        restart_budget: budget,
        faults: Arc::new(FaultPlan::inert().with_rate(FaultKind::Panic, 1.0)),
        ..Default::default()
    };
    let srv = InferenceServer::start(
        cfg,
        Arc::clone(&ds),
        template,
        EngineSnapshot::from_dataset(&ds, 0),
        None,
    );
    let mut admitted = 0usize;
    for _ in 0..10 {
        match srv.submit(vec![0, 1, 2]) {
            Ok(_) => admitted += 1,
            Err(ServeError::Degraded) => break,
            Err(other) => panic!("unexpected admission error {other:?}"),
        }
    }
    let responses = srv.drain(); // the liveness criterion: this returns
    assert_eq!(responses.len(), admitted, "exactly one response per admitted request");
    for r in &responses {
        assert!(
            matches!(r.err(), Some(ServeError::WorkerPanic { .. } | ServeError::Degraded)),
            "request {} must fail typed under a crash loop",
            r.id
        );
    }
    assert!(srv.is_degraded());
    let rep = srv.report("FaultStress");
    assert_eq!(rep.restarts, budget as u64, "respawns capped at the budget");
    assert_eq!(
        rep.panics,
        2 + budget as u64,
        "initial workers + respawned workers each died on their first request"
    );
    assert!(matches!(srv.submit(vec![0]), Err(ServeError::Degraded)));
    srv.shutdown();
}
