//! Integration: sharded mini-batch training end to end — partitioner →
//! neighbor sampler → direct CSR submatrix extraction → cached per-shard
//! format decisions → gradient accumulation → full-graph eval.
//!
//! Runs the `ogbn-arxiv-scale` synthetic spec shrunk degree-preservingly
//! for CI (tens of thousands of nodes; the full 169k-node graph is the
//! release-mode territory of `examples/minibatch_gcn.rs` and
//! `bench_minibatch`). Asserts the ISSUE-3 acceptance gates:
//! decision-cache hit rate > 80% after the first epoch, and zero
//! COO-fallback extractions (thread-local counter, exact for this run).

use gnn_spmm::gnn::engine::StaticPolicy;
use gnn_spmm::gnn::{train_minibatch, MinibatchConfig, ModelKind};
use gnn_spmm::graph::{GraphDataset, Partitioning, LARGE_DATASETS};
use gnn_spmm::sparse::Format;
use gnn_spmm::util::rng::Rng;

/// CI-scale ogbn-arxiv-scale: ~21k nodes, full-graph average degree
/// preserved (~13.7), features capped at 64 — still ≈ 4–8× the laptop-scale
/// Table-1 graphs every other harness trains full-batch. Set
/// `GNN_SPMM_FULL_SCALE=1` to run these tests on the unshrunk 169k-node
/// spec (release-mode recommended; the bench and example default to it).
fn arxiv_ci() -> GraphDataset {
    let spec = if std::env::var("GNN_SPMM_FULL_SCALE").is_ok() {
        LARGE_DATASETS[0]
    } else {
        LARGE_DATASETS[0].scaled_same_degree(8, 64)
    };
    let mut rng = Rng::new(0xA12C);
    GraphDataset::generate(&spec, &mut rng)
}

#[test]
fn minibatch_gcn_on_arxiv_scale_meets_acceptance_gates() {
    let ds = arxiv_ci();
    assert!(ds.adj.rows > 20_000, "CI graph should stay minibatch-scale");
    let cfg = MinibatchConfig {
        epochs: 3,
        hidden: 8,
        n_shards: 8,
        fanout: 6,
        seed: 0xBEEF,
        ..Default::default()
    };
    let mut policy = StaticPolicy(Format::Csr);
    let report = train_minibatch(ModelKind::Gcn, &ds, &mut policy, &cfg);

    // Completed a seeded multi-epoch run with per-shard decisions.
    assert_eq!(report.epoch_losses.len(), 3);
    assert!(report.epoch_losses.iter().all(|l| l.is_finite()));
    assert_eq!(report.test_accs.len(), 3);
    // Per-shard decisions actually happened: at least (X, A.l1, A.l2) per
    // shard per epoch plus evals.
    assert!(
        report.decisions.len() >= 3 * 8 * 3,
        "expected a decision stream, got {}",
        report.decisions.len()
    );

    // Acceptance gate 1: decision-cache hit rate > 80% after epoch 0.
    assert!(
        report.warm_cache_hit_rate > 0.8,
        "warm cache hit rate {:.3} (hits {} / misses {})",
        report.warm_cache_hit_rate,
        report.cache_hits,
        report.cache_misses
    );

    // Acceptance gate 2: extraction never round-trips CSR through COO.
    assert_eq!(
        report.coo_fallback_extractions, 0,
        "shard extraction must use the direct CSR path"
    );

    // The extraction + decision machinery is charged to the engine
    // stopwatch like every other overhead (paper accounting).
    assert!(report.phases.iter().any(|p| p.0 == "extract" && p.2 > 0));
}

#[test]
fn minibatch_run_is_seed_deterministic() {
    let ds = arxiv_ci();
    let cfg = MinibatchConfig {
        epochs: 2,
        hidden: 8,
        n_shards: 6,
        fanout: 4,
        seed: 0x5EED,
        ..Default::default()
    };
    let mut p1 = StaticPolicy(Format::Csr);
    let mut p2 = StaticPolicy(Format::Csr);
    let r1 = train_minibatch(ModelKind::Gcn, &ds, &mut p1, &cfg);
    let r2 = train_minibatch(ModelKind::Gcn, &ds, &mut p2, &cfg);
    assert_eq!(r1.epoch_losses.len(), r2.epoch_losses.len());
    for (a, b) in r1.epoch_losses.iter().zip(r2.epoch_losses.iter()) {
        assert!(
            (a - b).abs() <= 1e-5 * a.abs().max(1.0),
            "seeded runs diverged: {:?} vs {:?}",
            r1.epoch_losses,
            r2.epoch_losses
        );
    }
    assert_eq!(r1.final_test_acc, r2.final_test_acc);
    assert_eq!(r1.cache_hits, r2.cache_hits);
    assert_eq!(r1.cache_misses, r2.cache_misses);
}

#[test]
fn partitioner_covers_arxiv_scale_with_balanced_edges() {
    let ds = arxiv_ci();
    let part = Partitioning::by_degree(&ds.adj, 16);
    // Exact cover, disjoint.
    let mut seen = vec![false; ds.adj.rows];
    for shard in &part.shards {
        for &v in shard {
            assert!(!seen[v as usize], "node {v} in two shards");
            seen[v as usize] = true;
        }
    }
    assert!(seen.iter().all(|&s| s));
    // Degree balance: LPT bound (max ≤ min + heaviest node degree).
    let degrees: Vec<usize> = ds.adj.row_counts().iter().map(|&c| c as usize).collect();
    let loads = part.loads(&degrees);
    let wmax = degrees.iter().copied().max().unwrap();
    let (lo, hi) = (*loads.iter().min().unwrap(), *loads.iter().max().unwrap());
    assert!(hi <= lo + wmax.max(1), "shard edge loads unbalanced: {loads:?}");
}

#[test]
fn gat_and_film_minibatch_train_on_a_large_shard_stream() {
    // Smaller CI slice for the two heavier models: the point is that the
    // whole pipeline (pattern extraction for GAT, ρ recomputation for
    // FiLM) works on a sampled shard stream, not peak scale.
    let spec = LARGE_DATASETS[0].scaled_same_degree(32, 32);
    let mut rng = Rng::new(0xA12D);
    let ds = GraphDataset::generate(&spec, &mut rng);
    for kind in [ModelKind::Gat, ModelKind::Film] {
        let mut policy = StaticPolicy(Format::Csr);
        let report = train_minibatch(
            kind,
            &ds,
            &mut policy,
            &MinibatchConfig {
                epochs: 2,
                hidden: 8,
                n_shards: 4,
                fanout: 4,
                seed: 0xF00D,
                ..Default::default()
            },
        );
        assert_eq!(report.epoch_losses.len(), 2, "{}", kind.name());
        assert!(
            report.epoch_losses.iter().all(|l| l.is_finite()),
            "{}: {:?}",
            kind.name(),
            report.epoch_losses
        );
        assert_eq!(report.coo_fallback_extractions, 0, "{}", kind.name());
    }
}
