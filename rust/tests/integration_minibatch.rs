//! Integration: sharded mini-batch training end to end — partitioner →
//! neighbor sampler → direct CSR submatrix extraction → cached per-shard
//! format decisions → gradient accumulation → full-graph eval.
//!
//! Runs the `ogbn-arxiv-scale` synthetic spec shrunk degree-preservingly
//! for CI (tens of thousands of nodes; the full 169k-node graph is the
//! release-mode territory of the `minibatch_gcn`/`minibatch_rgcn` examples
//! and `bench_minibatch`). Asserts the ISSUE-3 acceptance gates
//! (decision-cache hit rate > 80% after the first epoch, zero COO-fallback
//! extractions — pool-aggregated counter, exact in this binary since no
//! test here produces fallbacks) and the ISSUE-4 gates (sharded RGCN/EGC ≡
//! full-batch step in the single-shard limit; per-relation extraction
//! direct on CSR/CSC/COO).

use gnn_spmm::gnn::engine::{AdjEngine, StaticPolicy};
use gnn_spmm::gnn::rgcn::{relation_operands, Rgcn, N_RELATIONS};
use gnn_spmm::gnn::{train_minibatch, train_minibatch_warm, MinibatchConfig, ModelKind};
use gnn_spmm::graph::{GraphDataset, Partitioning, LARGE_DATASETS};
use gnn_spmm::predictor::DecisionCache;
use gnn_spmm::sparse::{coo_fallback_extractions, Format, SparseMatrix};
use gnn_spmm::tensor::ops;
use gnn_spmm::util::rng::Rng;

/// CI-scale ogbn-arxiv-scale: ~21k nodes, full-graph average degree
/// preserved (~13.7), features capped at 64 — still ≈ 4–8× the laptop-scale
/// Table-1 graphs every other harness trains full-batch. Set
/// `GNN_SPMM_FULL_SCALE=1` to run these tests on the unshrunk 169k-node
/// spec (release-mode recommended; the bench and example default to it).
fn arxiv_ci() -> GraphDataset {
    let spec = if std::env::var("GNN_SPMM_FULL_SCALE").is_ok() {
        LARGE_DATASETS[0]
    } else {
        LARGE_DATASETS[0].scaled_same_degree(8, 64)
    };
    let mut rng = Rng::new(0xA12C);
    GraphDataset::generate(&spec, &mut rng)
}

#[test]
fn minibatch_gcn_on_arxiv_scale_meets_acceptance_gates() {
    let ds = arxiv_ci();
    assert!(ds.adj.rows > 20_000, "CI graph should stay minibatch-scale");
    let cfg = MinibatchConfig {
        epochs: 3,
        hidden: 8,
        n_shards: 8,
        fanout: 6,
        seed: 0xBEEF,
        ..Default::default()
    };
    let mut policy = StaticPolicy(Format::Csr);
    let report = train_minibatch(ModelKind::Gcn, &ds, &mut policy, &cfg);

    // Completed a seeded multi-epoch run with per-shard decisions.
    assert_eq!(report.epoch_losses.len(), 3);
    assert!(report.epoch_losses.iter().all(|l| l.is_finite()));
    assert_eq!(report.test_accs.len(), 3);
    // Per-shard decisions actually happened: at least (X, A.l1, A.l2) per
    // shard per epoch plus evals.
    assert!(
        report.decisions.len() >= 3 * 8 * 3,
        "expected a decision stream, got {}",
        report.decisions.len()
    );

    // Acceptance gate 1: decision-cache hit rate > 80% after epoch 0.
    assert!(
        report.warm_cache_hit_rate > 0.8,
        "warm cache hit rate {:.3} (hits {} / misses {})",
        report.warm_cache_hit_rate,
        report.cache_hits,
        report.cache_misses
    );

    // Acceptance gate 2: extraction never round-trips CSR through COO.
    assert_eq!(
        report.coo_fallback_extractions, 0,
        "shard extraction must use the direct CSR path"
    );

    // The extraction + decision machinery is charged to the engine
    // stopwatch like every other overhead (paper accounting).
    assert!(report.phases.iter().any(|p| p.0 == "extract" && p.2 > 0));
}

/// §Shared-Ownership acceptance gate: the decision cache round-trips
/// through JSON, and a warm-started run (fresh engine + policy, loaded
/// cache) achieves a hit rate at least as good as the in-memory warm rate
/// the cold run already guarantees (> 0.8) — the cold first epoch is gone.
#[test]
fn decision_cache_warm_start_round_trips_through_json() {
    let spec = LARGE_DATASETS[0].scaled_same_degree(32, 32);
    let mut rng = Rng::new(0xA131);
    let ds = GraphDataset::generate(&spec, &mut rng);
    let cfg = MinibatchConfig {
        epochs: 3,
        hidden: 8,
        n_shards: 6,
        fanout: 5,
        seed: 0xCAFE,
        ..Default::default()
    };
    let mut cold_policy = StaticPolicy(Format::Csr);
    let cold = train_minibatch(ModelKind::Gcn, &ds, &mut cold_policy, &cfg);
    assert!(
        cold.warm_cache_hit_rate > 0.8,
        "cold run warm rate {:.3}",
        cold.warm_cache_hit_rate
    );
    assert!(!cold.final_cache.is_empty(), "run must populate the cache");

    // Persist → reload (simulating a fresh process warm-starting).
    let dir = std::env::temp_dir().join("gnn_spmm_warmstart_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("decision_cache.json");
    cold.final_cache.save(&path).unwrap();
    let warm_cache = DecisionCache::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(warm_cache.len(), cold.final_cache.len(), "entry table must round-trip");
    assert_eq!(warm_cache.hits(), 0, "counters are run-local");

    // Same workload, fresh everything except the loaded cache: the run is
    // warm from the very first shard.
    let mut warm_policy = StaticPolicy(Format::Csr);
    let warm =
        train_minibatch_warm(ModelKind::Gcn, &ds, &mut warm_policy, &cfg, Some(warm_cache));
    let total = warm.cache_hits + warm.cache_misses;
    assert!(total > 0);
    let warm_run_rate = warm.cache_hits as f64 / total as f64;
    assert!(
        warm_run_rate + 1e-9 >= cold.warm_cache_hit_rate,
        "warm-started overall hit rate {warm_run_rate:.3} must be ≥ the cold run's \
         in-memory warm rate {:.3} (hits {} / misses {})",
        cold.warm_cache_hit_rate,
        warm.cache_hits,
        warm.cache_misses
    );
    // Numerics are untouched by warm-starting: decisions are the same
    // formats, just answered from the cache.
    assert_eq!(warm.final_test_acc, cold.final_test_acc);
}

#[test]
fn minibatch_run_is_seed_deterministic() {
    let ds = arxiv_ci();
    let cfg = MinibatchConfig {
        epochs: 2,
        hidden: 8,
        n_shards: 6,
        fanout: 4,
        seed: 0x5EED,
        ..Default::default()
    };
    let mut p1 = StaticPolicy(Format::Csr);
    let mut p2 = StaticPolicy(Format::Csr);
    let r1 = train_minibatch(ModelKind::Gcn, &ds, &mut p1, &cfg);
    let r2 = train_minibatch(ModelKind::Gcn, &ds, &mut p2, &cfg);
    assert_eq!(r1.epoch_losses.len(), r2.epoch_losses.len());
    for (a, b) in r1.epoch_losses.iter().zip(r2.epoch_losses.iter()) {
        assert!(
            (a - b).abs() <= 1e-5 * a.abs().max(1.0),
            "seeded runs diverged: {:?} vs {:?}",
            r1.epoch_losses,
            r2.epoch_losses
        );
    }
    assert_eq!(r1.final_test_acc, r2.final_test_acc);
    assert_eq!(r1.cache_hits, r2.cache_hits);
    assert_eq!(r1.cache_misses, r2.cache_misses);
}

#[test]
fn partitioner_covers_arxiv_scale_with_balanced_edges() {
    let ds = arxiv_ci();
    let part = Partitioning::by_degree(&ds.adj, 16);
    // Exact cover, disjoint.
    let mut seen = vec![false; ds.adj.rows];
    for shard in &part.shards {
        for &v in shard {
            assert!(!seen[v as usize], "node {v} in two shards");
            seen[v as usize] = true;
        }
    }
    assert!(seen.iter().all(|&s| s));
    // Degree balance: LPT bound (max ≤ min + heaviest node degree).
    let degrees: Vec<usize> = ds.adj.row_counts().iter().map(|&c| c as usize).collect();
    let loads = part.loads(&degrees);
    let wmax = degrees.iter().copied().max().unwrap();
    let (lo, hi) = (*loads.iter().min().unwrap(), *loads.iter().max().unwrap());
    assert!(hi <= lo + wmax.max(1), "shard edge loads unbalanced: {loads:?}");
}

/// ISSUE-4 acceptance gate: with one shard and unbounded fan-out the
/// induced batch is the identity selection, so the sharded RGCN/EGC step
/// must reproduce the full-batch step (same seed) — the shard-weighted
/// accumulation is exactly the full-batch train-set mean gradient.
#[test]
fn rgcn_egc_single_shard_matches_full_batch_step() {
    let spec = LARGE_DATASETS[0].scaled_same_degree(32, 32);
    let mut rng = Rng::new(0xA12E);
    let ds = GraphDataset::generate(&spec, &mut rng);
    for kind in [ModelKind::Rgcn, ModelKind::Egc] {
        let cfg = MinibatchConfig {
            epochs: 3,
            hidden: 8,
            n_shards: 1,
            fanout: usize::MAX,
            seed: 0xD00D,
            ..Default::default()
        };
        let mut policy = StaticPolicy(Format::Csr);
        let report = train_minibatch(kind, &ds, &mut policy, &cfg);
        assert_eq!(
            report.coo_fallback_extractions, 0,
            "{}: identity extraction must stay on direct paths",
            kind.name()
        );

        // Manual full-batch reference: identical construction (same seed
        // consumed the same way), identical per-epoch step, eval after.
        let mut mrng = Rng::new(cfg.seed);
        let mut mpolicy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut mpolicy);
        let mut losses = Vec::new();
        let mut accs = Vec::new();
        match kind {
            ModelKind::Rgcn => {
                let mut m = Rgcn::new(&ds, cfg.hidden, cfg.lr, &mut mrng, &mut eng);
                for _ in 0..cfg.epochs {
                    let logits = m.forward(&mut eng);
                    let (loss, dlogits) =
                        ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
                    m.backward(&mut eng, &dlogits);
                    losses.push(loss);
                    let eval = m.forward(&mut eng);
                    accs.push(ops::masked_accuracy(&eval, &ds.labels, &ds.train_mask));
                }
            }
            ModelKind::Egc => {
                let mut m =
                    gnn_spmm::gnn::egc::Egc::new(&ds, cfg.hidden, cfg.lr, &mut mrng, &mut eng);
                for _ in 0..cfg.epochs {
                    let logits = m.forward(&mut eng);
                    let (loss, dlogits) =
                        ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
                    m.backward(&mut eng, &dlogits);
                    losses.push(loss);
                    let eval = m.forward(&mut eng);
                    accs.push(ops::masked_accuracy(&eval, &ds.labels, &ds.train_mask));
                }
            }
            _ => unreachable!(),
        }

        assert_eq!(report.epoch_losses.len(), losses.len(), "{}", kind.name());
        for (e, (a, b)) in report.epoch_losses.iter().zip(losses.iter()).enumerate() {
            assert!(
                (a - b).abs() <= 5e-3 * a.abs().max(1.0),
                "{} epoch {e}: sharded loss {a} vs full-batch {b}",
                kind.name()
            );
        }
        for (e, (a, b)) in report.train_accs.iter().zip(accs.iter()).enumerate() {
            assert!(
                (a - b).abs() <= 0.02,
                "{} epoch {e}: sharded train acc {a} vs full-batch {b}",
                kind.name()
            );
        }
    }
}

/// ISSUE-4 acceptance gate: per-relation shard extraction takes the direct
/// path (zero COO fallbacks) whichever of CSR/CSC/COO holds the relation
/// masters, and the extracted submatrices match the dense reference.
#[test]
fn per_relation_extraction_is_direct_for_csr_csc_coo() {
    let spec = LARGE_DATASETS[0].scaled_same_degree(64, 16);
    let mut rng = Rng::new(0xA12F);
    let ds = GraphDataset::generate(&spec, &mut rng);
    let rels = relation_operands(&ds.adj);
    assert_eq!(rels.len(), N_RELATIONS);
    // A plausible shard node selection: every third node.
    let nodes: Vec<u32> = (0..ds.adj.rows as u32).step_by(3).collect();

    let before = coo_fallback_extractions();
    for (r, rel) in rels.iter().enumerate() {
        let dense = rel.to_dense();
        let mut want =
            gnn_spmm::tensor::Matrix::zeros(nodes.len(), nodes.len());
        for (nr, &or) in nodes.iter().enumerate() {
            for (nc, &oc) in nodes.iter().enumerate() {
                *want.at_mut(nr, nc) = dense.at(or as usize, oc as usize);
            }
        }
        for fmt in [Format::Csr, Format::Csc, Format::Coo] {
            let master = SparseMatrix::Coo(rel.clone()).convert(fmt).unwrap();
            let sub = master.extract_rows_cols(&nodes, &nodes);
            assert_eq!(sub.format(), fmt, "relation {r}: direct path keeps {fmt}");
            assert_eq!(
                sub.to_dense().max_abs_diff(&want),
                0.0,
                "relation {r} ({fmt}): extracted submatrix mismatch"
            );
        }
    }
    assert_eq!(
        coo_fallback_extractions(),
        before,
        "CSR/CSC/COO relation extraction must never hit the COO fallback"
    );
}

/// Sharded RGCN at CI scale: the relation × shard decision stream flows
/// through the cache (one entry per relation slot per shard signature)
/// and never leaves the direct extraction paths.
#[test]
fn rgcn_minibatch_on_arxiv_ci_scale() {
    let spec = LARGE_DATASETS[0].scaled_same_degree(32, 32);
    let mut rng = Rng::new(0xA130);
    let ds = GraphDataset::generate(&spec, &mut rng);
    let cfg = MinibatchConfig {
        epochs: 2,
        hidden: 8,
        n_shards: 6,
        fanout: 5,
        seed: 0xFEED,
        ..Default::default()
    };
    let mut policy = StaticPolicy(Format::Csr);
    let report = train_minibatch(ModelKind::Rgcn, &ds, &mut policy, &cfg);
    assert_eq!(report.epoch_losses.len(), 2);
    assert!(report.epoch_losses.iter().all(|l| l.is_finite()));
    assert_eq!(report.coo_fallback_extractions, 0);
    // Both layers of every relation slot produced decisions.
    for r in 0..N_RELATIONS {
        for layer in 1..=2 {
            let slot = format!("rgcn.A{r}.l{layer}");
            assert!(
                report.decisions.iter().any(|d| d.slot == slot),
                "no decisions recorded for {slot}"
            );
        }
    }
    // The shard stream reuses cached decisions after warmup.
    assert!(
        report.warm_cache_hit_rate > 0.5,
        "warm hit rate {:.3} (hits {}, misses {})",
        report.warm_cache_hit_rate,
        report.cache_hits,
        report.cache_misses
    );
}

#[test]
fn gat_and_film_minibatch_train_on_a_large_shard_stream() {
    // Smaller CI slice for the two heavier models: the point is that the
    // whole pipeline (pattern extraction for GAT, ρ recomputation for
    // FiLM) works on a sampled shard stream, not peak scale.
    let spec = LARGE_DATASETS[0].scaled_same_degree(32, 32);
    let mut rng = Rng::new(0xA12D);
    let ds = GraphDataset::generate(&spec, &mut rng);
    for kind in [ModelKind::Gat, ModelKind::Film] {
        let mut policy = StaticPolicy(Format::Csr);
        let report = train_minibatch(
            kind,
            &ds,
            &mut policy,
            &MinibatchConfig {
                epochs: 2,
                hidden: 8,
                n_shards: 4,
                fanout: 4,
                seed: 0xF00D,
                ..Default::default()
            },
        );
        assert_eq!(report.epoch_losses.len(), 2, "{}", kind.name());
        assert!(
            report.epoch_losses.iter().all(|l| l.is_finite()),
            "{}: {:?}",
            kind.name(),
            report.epoch_losses
        );
        assert_eq!(report.coo_fallback_extractions, 0, "{}", kind.name());
    }
}
