//! Pool-safety of the COO-fallback extraction counter.
//!
//! This lives in its own test binary on purpose: it deliberately produces
//! COO-fallback extractions **on `util::pool` worker threads**, which land
//! in the shared pool-side counter. Any test that asserts a zero fallback
//! delta (the minibatch suite) runs in a different process and stays
//! exact.

use gnn_spmm::sparse::{coo_fallback_extractions, Coo, Dok, SparseMatrix, SparseOps};
use gnn_spmm::util::parallel::parallel_map;
use gnn_spmm::util::rng::Rng;

fn random_dok(rng: &mut Rng, n: usize) -> Dok {
    let mut triples = Vec::new();
    for r in 0..n {
        for c in 0..n {
            if rng.bernoulli(0.2) {
                triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
            }
        }
    }
    Dok::from_coo(&Coo::from_triples(n, n, triples))
}

/// Fallback extractions dispatched across the worker pool must all be
/// visible to the measuring thread. Before the pool-aggregated counter, a
/// worker-side extraction bumped only the worker's thread-local and the
/// caller's delta silently read zero. Under `GNN_SPMM_THREADS=1` every
/// task runs inline on the caller, which the sum covers equally.
#[test]
fn pool_worker_fallbacks_are_visible_to_the_caller() {
    let mut rng = Rng::new(0xFA11);
    let dok = random_dok(&mut rng, 24);
    let rows: Vec<u32> = vec![0, 3, 5, 11, 20];
    let cols: Vec<u32> = vec![1, 2, 8, 15];
    let want = {
        let full = dok.to_coo().to_dense();
        let mut m = gnn_spmm::tensor::Matrix::zeros(rows.len(), cols.len());
        for (nr, &r) in rows.iter().enumerate() {
            for (nc, &c) in cols.iter().enumerate() {
                *m.at_mut(nr, nc) = full.at(r as usize, c as usize);
            }
        }
        m
    };

    let n_tasks = 8;
    let before = coo_fallback_extractions();
    let subs = parallel_map(n_tasks, |_| SparseOps::extract_rows_cols(&dok, &rows, &cols));
    assert_eq!(
        coo_fallback_extractions() - before,
        n_tasks as u64,
        "every pool-dispatched fallback extraction must be counted"
    );
    for sub in &subs {
        assert!(matches!(sub, SparseMatrix::Coo(_)), "fallback lands in COO");
        assert_eq!(sub.to_dense().max_abs_diff(&want), 0.0);
    }

    // Inline (non-pool) fallbacks keep counting through the same getter.
    let before = coo_fallback_extractions();
    let _ = SparseOps::extract_rows_cols(&dok, &rows, &cols);
    assert_eq!(coo_fallback_extractions() - before, 1);
}
