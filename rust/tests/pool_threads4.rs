//! Multi-threaded execution pin: `GNN_SPMM_THREADS=4` forces pooled
//! dispatch (3 parked workers + the caller) regardless of the machine's
//! core count, exercising weighted-span scheduling and the scatter-reduce
//! scratch path. Its own process, so the pin cannot race with other test
//! binaries.

mod common;

#[test]
fn formats_match_dense_four_threads() {
    std::env::set_var("GNN_SPMM_THREADS", "4");
    assert_eq!(gnn_spmm::util::parallel::num_threads(), 4);
    common::check_formats_vs_dense();
}

/// The full schedule space under pooled dispatch: thread caps below, at and
/// above the pin (Cap(1) serial, Cap(3) partial, Auto = all 4) all agree
/// with dense math through the weighted-span and scatter-reduce paths.
#[test]
fn schedule_space_matches_dense_four_threads() {
    std::env::set_var("GNN_SPMM_THREADS", "4");
    assert_eq!(gnn_spmm::util::parallel::num_threads(), 4);
    common::check_schedules_vs_dense();
}
