//! Integration: the PJRT runtime loads the JAX/Pallas AOT artifacts and the
//! numerics match the native rust implementations. Skipped (with a message)
//! when `artifacts/` hasn't been built — run `make artifacts` first.
//! Compiled only with `--features pjrt` (the `runtime` module is gated).
#![cfg(feature = "pjrt")]

use gnn_spmm::runtime::{default_artifacts_dir, PjrtEngine};
use gnn_spmm::sparse::{Bsr, Coo};
use gnn_spmm::tensor::{ops, Matrix};
use gnn_spmm::util::rng::Rng;

fn engine_or_skip() -> Option<PjrtEngine> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        return None;
    }
    let mut eng = PjrtEngine::cpu().expect("PJRT CPU client");
    eng.load_manifest(&dir).expect("load artifacts");
    Some(eng)
}

// Shapes must match python/compile/aot.py constants.
const N: usize = 677;
const H: usize = 16;
const C: usize = 7;
const BS: usize = 16;
const NRB: usize = 43;
const NPAD: usize = NRB * BS;
const NNZB_CAP: usize = 4096;
const DSP: usize = 32;

#[test]
fn loads_all_manifest_artifacts() {
    let Some(eng) = engine_or_skip() else { return };
    for name in ["gcn_layer_fwd", "gcn_loss_grad", "gcn_layer_bwd", "bsr_spmm_demo"] {
        assert!(eng.has(name), "missing artifact {name}");
    }
    assert!(!eng.platform().is_empty());
}

#[test]
fn gcn_layer_fwd_matches_native() {
    let Some(eng) = engine_or_skip() else { return };
    let mut rng = Rng::new(1);
    let s0 = Matrix::rand(N, H, &mut rng);
    let b0 = Matrix::rand(1, H, &mut rng);
    let w1 = Matrix::rand(H, C, &mut rng);
    let out = eng.run("gcn_layer_fwd", &[&s0, &b0, &w1]).expect("run");
    assert_eq!(out.len(), 2);
    // Native: h1 = relu(s0 + b0); z1 = h1 @ w1.
    let h1 = ops::relu(&ops::add_row(&s0, &b0.data));
    let z1 = h1.matmul(&w1);
    assert!(out[0].max_abs_diff(&h1) < 1e-4, "H1 mismatch");
    assert!(out[1].max_abs_diff(&z1) < 1e-3, "Z1 mismatch");
}

#[test]
fn gcn_loss_grad_matches_native() {
    let Some(eng) = engine_or_skip() else { return };
    let mut rng = Rng::new(2);
    let logits = Matrix::rand(N, C, &mut rng);
    let labels: Vec<usize> = (0..N).map(|_| rng.gen_range(C)).collect();
    let mask_vec: Vec<bool> = (0..N).map(|_| rng.bernoulli(0.6)).collect();
    let mut y = Matrix::zeros(N, C);
    let mut mask = Matrix::zeros(N, 1);
    for i in 0..N {
        *y.at_mut(i, labels[i]) = 1.0;
        mask.data[i] = f32::from(mask_vec[i]);
    }
    let out = eng.run("gcn_loss_grad", &[&logits, &y, &mask]).expect("run");
    let (loss_native, grad_native) = ops::masked_xent_with_grad(&logits, &labels, &mask_vec);
    assert!(
        (out[0].data[0] - loss_native).abs() < 1e-4,
        "loss {} vs native {}",
        out[0].data[0],
        loss_native
    );
    assert!(out[1].max_abs_diff(&grad_native) < 1e-5, "dlogits mismatch");
}

#[test]
fn gcn_layer_bwd_matches_native() {
    let Some(eng) = engine_or_skip() else { return };
    let mut rng = Rng::new(3);
    let s0 = Matrix::rand(N, H, &mut rng);
    let b0 = Matrix::rand(1, H, &mut rng);
    let w1 = Matrix::rand(H, C, &mut rng);
    let dz1 = Matrix::rand(N, C, &mut rng);
    let out = eng.run("gcn_layer_bwd", &[&s0, &b0, &w1, &dz1]).expect("run");
    // Native backward.
    let pre = ops::add_row(&s0, &b0.data);
    let h1 = ops::relu(&pre);
    let dw1 = h1.t_matmul(&dz1);
    let dh1 = dz1.matmul_t(&w1);
    let ds0 = ops::relu_grad(&pre, &dh1);
    assert!(out[0].max_abs_diff(&dw1) < 2e-3, "dW1 mismatch");
    assert!(out[1].max_abs_diff(&ds0) < 1e-3, "dS0 mismatch");
}

/// The L1 Pallas artifact (interpret-mode BSR SpMM) agrees with the rust
/// BSR kernel — the full L1 → L2 → L3 composition check.
#[test]
fn pallas_bsr_spmm_matches_rust_bsr() {
    let Some(eng) = engine_or_skip() else { return };
    let mut rng = Rng::new(4);
    // Random sparse matrix within the padded capacity.
    let mut triples = Vec::new();
    for r in 0..N {
        for _ in 0..3 {
            triples.push((r as u32, rng.gen_range(N) as u32, rng.uniform(-1.0, 1.0) as f32));
        }
    }
    let coo = Coo::from_triples(N, N, triples);
    let bsr = Bsr::from_coo(&coo, BS);
    assert!(bsr.n_blocks() <= NNZB_CAP, "demo capacity exceeded");
    // bsr.indptr covers ceil(N/BS) = NRB row blocks exactly (677 → 43).
    assert_eq!(bsr.indptr.len(), NRB + 1);

    // Pack padded BSR arrays as f32 matrices for the artifact.
    let mut indptr = Matrix::zeros(1, NRB + 1);
    for (i, &p) in bsr.indptr.iter().enumerate() {
        indptr.data[i] = p as f32;
    }
    let mut indices = Matrix::zeros(1, NNZB_CAP);
    for (i, &c) in bsr.indices.iter().enumerate() {
        indices.data[i] = c as f32;
    }
    let mut blocks = Matrix::zeros(NNZB_CAP * BS, BS);
    blocks.data[..bsr.blocks.len()].copy_from_slice(&bsr.blocks);
    let mut x = Matrix::zeros(NPAD, DSP);
    for r in 0..N {
        for c in 0..DSP {
            *x.at_mut(r, c) = rng.next_f32();
        }
    }

    let out = eng
        .run("bsr_spmm_demo", &[&indptr, &indices, &blocks, &x])
        .expect("run pallas artifact");
    assert_eq!(out[0].shape(), (NPAD, DSP));

    // Rust-side reference: BSR spmm on the unpadded operand.
    let x_unpadded = Matrix::from_vec(
        N,
        DSP,
        (0..N).flat_map(|r| x.row(r).to_vec()).collect(),
    );
    let want = bsr.spmm(&x_unpadded);
    for r in 0..N {
        for c in 0..DSP {
            let a = out[0].at(r, c);
            let b = want.at(r, c);
            assert!(
                (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                "mismatch at ({r},{c}): {a} vs {b}"
            );
        }
    }
}
