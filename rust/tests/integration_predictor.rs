//! Integration: the offline pipeline (corpus → labels → GBDT) produces a
//! predictor that beats always-COO on matrices it has never seen, and the
//! §4.6 SpMMPredict API behaves end-to-end.

use gnn_spmm::ml::Classifier;
use gnn_spmm::predictor::labeler::{label_for, profile_formats};
use gnn_spmm::predictor::spmm_predict::spmm_predict;
use gnn_spmm::predictor::training::{train_predictor, TrainingCorpus};
use gnn_spmm::graph::{gen_matrix, MatrixPattern};
use gnn_spmm::sparse::SparseMatrix;
use gnn_spmm::tensor::Matrix;
use gnn_spmm::util::rng::Rng;

#[test]
fn predictor_choices_track_oracle_on_unseen_matrices() {
    let corpus = TrainingCorpus::build(60, 64, 256, 16, 2, 0x1234);
    let pred = train_predictor(&corpus, 1.0, 5);
    assert!(pred.cv_accuracy > 0.35, "cv acc {}", pred.cv_accuracy);

    // Unseen matrices: measure how often the predicted format is within
    // 1.5x of the oracle-best SpMM time (top-1 label match is strict; the
    // paper's metric of interest is realized performance).
    let mut rng = Rng::new(0x777);
    let mut good = 0usize;
    let total = 20usize;
    for i in 0..total {
        let pattern = match i % 4 {
            0 => MatrixPattern::Uniform,
            1 => MatrixPattern::PowerLaw,
            2 => MatrixPattern::Banded,
            _ => MatrixPattern::Block,
        };
        let m = gen_matrix(&mut rng, 128 + (i % 5) * 64, 0.02 + 0.02 * (i % 4) as f64, pattern);
        let profiles = profile_formats(&m, 16, 3);
        let best_time = profiles
            .iter()
            .filter_map(|p| p.effective_secs())
            .fold(f64::INFINITY, f64::min);
        let chosen = pred.predict(&m);
        let chosen_time = profiles
            .iter()
            .find(|p| p.format == chosen)
            .and_then(|p| p.effective_secs())
            .unwrap_or(f64::INFINITY);
        if chosen_time <= best_time * 1.5 {
            good += 1;
        }
    }
    assert!(
        good * 2 >= total,
        "predicted format should be near-optimal on most unseen matrices: {good}/{total}"
    );
}

#[test]
fn eq1_labels_match_manual_objective() {
    let mut rng = Rng::new(9);
    let m = gen_matrix(&mut rng, 128, 0.05, MatrixPattern::Diagonal);
    let profiles = profile_formats(&m, 8, 2);
    for &w in &[0.0, 0.5, 1.0] {
        let label = label_for(&profiles, w);
        // Recompute O manually and verify the label minimizes it.
        let times: Vec<f64> = profiles.iter().filter_map(|p| p.effective_secs()).collect();
        let mems: Vec<f64> = profiles.iter().filter_map(|p| p.nbytes.map(|b| b as f64)).collect();
        let (tl, th) = (
            times.iter().cloned().fold(f64::INFINITY, f64::min),
            times.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        );
        let (ml, mh) = (
            mems.iter().cloned().fold(f64::INFINITY, f64::min),
            mems.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        );
        let o_of = |p: &gnn_spmm::predictor::labeler::FormatProfile| -> f64 {
            let t = p.effective_secs().unwrap();
            let b = p.nbytes.unwrap() as f64;
            let r = if th > tl { (t - tl) / (th - tl) } else { 0.0 };
            let m = if mh > ml { (b - ml) / (mh - ml) } else { 0.0 };
            w * r + (1.0 - w) * m
        };
        let label_o = profiles.iter().find(|p| p.format == label).map(&o_of).unwrap();
        for p in profiles.iter().filter(|p| p.spmm_secs.is_some()) {
            assert!(label_o <= o_of(p) + 1e-12, "label not optimal at w={w}");
        }
    }
}

#[test]
fn spmm_predict_api_end_to_end() {
    let corpus = TrainingCorpus::build(30, 64, 192, 16, 1, 0x42);
    let pred = train_predictor(&corpus, 1.0, 3);
    let mut rng = Rng::new(10);
    let coo = gen_matrix(&mut rng, 200, 0.03, MatrixPattern::PowerLaw);
    let input = SparseMatrix::Coo(coo);
    let stored = spmm_predict(&pred, &input);
    let x = Matrix::rand(200, 8, &mut rng);
    assert!(stored.spmm(&x).max_abs_diff(&input.spmm(&x)) < 1e-4);
}

#[test]
fn predictor_persistence_through_file() {
    let corpus = TrainingCorpus::build(25, 64, 128, 8, 1, 0x99);
    let pred = train_predictor(&corpus, 0.5, 11);
    let path = std::env::temp_dir().join("gnn_spmm_pred_test/predictor.json");
    pred.save(&path).unwrap();
    let loaded = gnn_spmm::predictor::training::TrainedPredictor::load(&path).unwrap();
    assert_eq!(loaded.w, 0.5);
    let mut rng = Rng::new(12);
    for _ in 0..5 {
        let m = gen_matrix(&mut rng, 100, 0.05, MatrixPattern::Uniform);
        assert_eq!(pred.predict(&m), loaded.predict(&m));
    }
    let _ = std::fs::remove_file(path);
}

#[test]
fn gbdt_importance_covers_features() {
    let corpus = TrainingCorpus::build(40, 64, 192, 16, 1, 0x31);
    let (data, _) = corpus.dataset(1.0);
    let model = gnn_spmm::ml::gbdt::Gbdt::fit(&data, Default::default());
    let imp = model.importance();
    assert_eq!(imp.len(), gnn_spmm::features::N_FEATURES);
    let used = imp.iter().filter(|&&v| v > 0.0).count();
    assert!(used >= 3, "GBDT should split on several features: {used}");
    // Sanity: model predicts in label range.
    for x in data.x.iter().take(10) {
        assert!(model.predict(x) < 7);
    }
}
