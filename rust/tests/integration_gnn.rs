//! Integration: all five GNNs train end-to-end under every policy type and
//! produce format-invariant numerics; the predicted policy actually switches
//! formats away from COO when it pays.

use gnn_spmm::gnn::engine::{SlotTargetedPolicy, StaticPolicy};
use gnn_spmm::gnn::{train, ModelKind, TrainConfig, ALL_MODELS};
use gnn_spmm::graph::{DatasetSpec, GraphDataset};
use gnn_spmm::predictor::policy::{OraclePolicy, PredictedPolicy};
use gnn_spmm::predictor::training::{train_predictor, TrainingCorpus};
use gnn_spmm::sparse::Format;
use gnn_spmm::util::rng::Rng;

fn dataset(seed: u64, n: usize) -> GraphDataset {
    let mut rng = Rng::new(seed);
    GraphDataset::generate(
        &DatasetSpec {
            name: "IntGnn",
            n,
            feat_dim: 32,
            adj_density: 0.04,
            feat_density: 0.15,
            n_classes: 4,
        },
        &mut rng,
    )
}

#[test]
fn all_models_learn_under_predicted_policy() {
    let ds = dataset(1, 150);
    let corpus = TrainingCorpus::build(25, 64, 160, 16, 1, 0xF00D);
    for kind in ALL_MODELS {
        let pred = train_predictor(&corpus, 1.0, 2);
        let mut policy = PredictedPolicy::new(pred);
        let report = train(
            kind,
            &ds,
            &mut policy,
            &TrainConfig { epochs: 10, hidden: 8, ..Default::default() },
        );
        assert!(
            *report.losses.last().unwrap() < report.losses[0],
            "{}: loss did not drop under predicted policy",
            kind.name()
        );
        assert!(!report.decisions.is_empty());
    }
}

#[test]
fn oracle_policy_trains_gcn() {
    let ds = dataset(2, 120);
    let mut policy = OraclePolicy { reps: 1, w: 1.0 };
    let report = train(
        ModelKind::Gcn,
        &ds,
        &mut policy,
        &TrainConfig { epochs: 6, hidden: 8, ..Default::default() },
    );
    assert!(*report.losses.last().unwrap() < report.losses[0]);
    // Oracle decisions should cover the engine slots.
    assert!(report.decisions.len() >= 4);
}

#[test]
fn policies_do_not_change_numerics() {
    let ds = dataset(3, 100);
    let cfg = TrainConfig { epochs: 5, hidden: 8, seed: 0xABCD, ..Default::default() };
    let mut p1 = StaticPolicy(Format::Coo);
    let r1 = train(ModelKind::Gcn, &ds, &mut p1, &cfg);
    let mut p2 = OraclePolicy { reps: 1, w: 1.0 };
    let r2 = train(ModelKind::Gcn, &ds, &mut p2, &cfg);
    let mut p3 = SlotTargetedPolicy {
        needle: "H1",
        special: Format::Lil,
        default: Format::Bsr,
    };
    let r3 = train(ModelKind::Gcn, &ds, &mut p3, &cfg);
    for (a, b) in r1.losses.iter().zip(r2.losses.iter()) {
        assert!((a - b).abs() < 2e-3, "oracle changed numerics: {a} vs {b}");
    }
    for (a, b) in r1.losses.iter().zip(r3.losses.iter()) {
        assert!((a - b).abs() < 2e-3, "format mix changed numerics: {a} vs {b}");
    }
}

#[test]
fn phase_accounting_covers_overheads() {
    // Big enough that the adjacency clears MIN_NNZ_TO_PREDICT.
    let ds = dataset(4, 400);
    let corpus = TrainingCorpus::build(20, 64, 128, 8, 1, 0xFEE);
    let pred = train_predictor(&corpus, 1.0, 2);
    let mut policy = PredictedPolicy::new(pred);
    let report = train(
        ModelKind::Gcn,
        &ds,
        &mut policy,
        &TrainConfig { epochs: 5, hidden: 8, ..Default::default() },
    );
    let phases: Vec<&str> = report.phases.iter().map(|(p, _, _)| *p).collect();
    assert!(phases.contains(&"spmm"), "spmm must be measured: {phases:?}");
    assert!(
        phases.contains(&"feature_extract") && phases.contains(&"predict"),
        "predictor overheads must be charged: {phases:?}"
    );
}

#[test]
fn h1_density_drifts_during_training() {
    // The Fig-2 signal: layer-1 activation density changes across epochs.
    let ds = dataset(5, 200);
    let mut policy = StaticPolicy(Format::Csr);
    let report = train(
        ModelKind::Gcn,
        &ds,
        &mut policy,
        &TrainConfig { epochs: 20, hidden: 16, ..Default::default() },
    );
    let first = report.h1_densities[0];
    let last = *report.h1_densities.last().unwrap();
    assert!(
        (first - last).abs() > 1e-4,
        "H1 density should drift over training: {first} -> {last}"
    );
}
