//! Cross-module integration: formats × conversions × SpMM at dataset scale,
//! plus memory-model and transpose interplay used by the GNN engine.

use gnn_spmm::graph::{gen_matrix, normalize_adj, DatasetSpec, GraphDataset, MatrixPattern};
use gnn_spmm::sparse::{Format, SparseMatrix, ALL_FORMATS};
use gnn_spmm::tensor::Matrix;
use gnn_spmm::util::rng::Rng;

#[test]
fn every_format_agrees_on_a_real_dataset_adjacency() {
    let mut rng = Rng::new(1);
    let spec = DatasetSpec {
        name: "IntTest",
        n: 600,
        feat_dim: 64,
        adj_density: 0.02,
        feat_density: 0.1,
        n_classes: 4,
    };
    let ds = GraphDataset::generate(&spec, &mut rng);
    let x = Matrix::rand(600, 16, &mut rng);
    let base = SparseMatrix::Coo(ds.adj_norm.clone());
    let want = base.spmm(&x);
    for &fmt in &ALL_FORMATS {
        let Ok(m) = base.convert(fmt) else { continue };
        let got = m.spmm(&x);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-3, "{fmt}: diff {diff}");
    }
}

#[test]
fn chained_conversions_preserve_content() {
    // COO -> CSR -> BSR -> LIL -> DOK -> CSC -> COO must be lossless.
    let mut rng = Rng::new(2);
    let coo = gen_matrix(&mut rng, 200, 0.05, MatrixPattern::PowerLaw);
    let mut m = SparseMatrix::Coo(coo.clone());
    for fmt in [Format::Csr, Format::Bsr, Format::Lil, Format::Dok, Format::Csc, Format::Coo] {
        m = m.convert(fmt).unwrap();
    }
    assert_eq!(m.to_coo(), coo);
}

#[test]
fn normalized_adjacency_keeps_spmm_bounded() {
    // Â has spectral radius ≤ 1, so repeated propagation must not blow up.
    let mut rng = Rng::new(3);
    let adj = gen_matrix(&mut rng, 300, 0.03, MatrixPattern::Uniform);
    // Symmetrize.
    let mut triples = Vec::new();
    for i in 0..adj.nnz() {
        triples.push((adj.row[i], adj.col[i], 1.0f32));
        triples.push((adj.col[i], adj.row[i], 1.0f32));
    }
    let sym = gnn_spmm::sparse::Coo::from_triples(300, 300, triples);
    let norm = normalize_adj(&sym);
    let m = SparseMatrix::Csr(gnn_spmm::sparse::Csr::from_coo(&norm));
    let mut x = Matrix::full(300, 8, 1.0);
    for _ in 0..20 {
        x = m.spmm(&x);
    }
    assert!(x.data.iter().all(|v| v.is_finite()));
    assert!(x.norm() <= 300.0 * 8.0, "propagation should stay bounded");
}

#[test]
fn transpose_roundtrip_spmm_consistency() {
    // (Aᵀ)ᵀ x == A x across formats — the gradient-path invariant.
    let mut rng = Rng::new(4);
    let coo = gen_matrix(&mut rng, 150, 0.08, MatrixPattern::Block);
    let x = Matrix::rand(150, 8, &mut rng);
    let base = SparseMatrix::Coo(coo);
    let want = base.spmm(&x);
    for &fmt in &[Format::Csr, Format::Csc, Format::Bsr] {
        let m = base.convert(fmt).unwrap();
        let tt = m.transpose().unwrap().transpose().unwrap();
        assert!(tt.spmm(&x).max_abs_diff(&want) < 1e-4, "{fmt}");
    }
}

#[test]
fn spmm_t_agrees_across_formats_at_dataset_scale() {
    // The gradient-path kernel: Aᵀ·X via spmm_t on each format's own arrays
    // must match the materialized transpose across the whole format set.
    let mut rng = Rng::new(6);
    let coo = gen_matrix(&mut rng, 500, 0.03, MatrixPattern::PowerLaw);
    let x = Matrix::rand(500, 16, &mut rng);
    let base = SparseMatrix::Coo(coo.clone());
    let want = SparseMatrix::Coo(coo.transpose()).spmm(&x);
    for &fmt in &ALL_FORMATS {
        let Ok(m) = base.convert(fmt) else { continue };
        let got = m.spmm_t(&x);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-3, "{fmt}: spmm_t diff {diff}");
    }
}

#[test]
fn spmm_into_reuses_buffer_without_residue() {
    // Run two different matrices through the same output buffer; the second
    // result must show no residue from the first (the workspace contract).
    let mut rng = Rng::new(7);
    let a = gen_matrix(&mut rng, 300, 0.05, MatrixPattern::Uniform);
    let b = gen_matrix(&mut rng, 300, 0.01, MatrixPattern::PowerLaw);
    let x = Matrix::rand(300, 8, &mut rng);
    let ma = SparseMatrix::Coo(a).convert(Format::Csr).unwrap();
    let mb = SparseMatrix::Coo(b).convert(Format::Csr).unwrap();
    let mut out = Matrix::zeros(300, 8);
    ma.spmm_into(&x, &mut out);
    mb.spmm_into(&x, &mut out);
    assert!(out.max_abs_diff(&mb.spmm(&x)) < 1e-5, "stale residue in reused buffer");
}

#[test]
fn direct_transpose_paths_match_coo_hub() {
    let mut rng = Rng::new(8);
    let coo = gen_matrix(&mut rng, 200, 0.04, MatrixPattern::Block);
    let want = coo.transpose();
    let base = SparseMatrix::Coo(coo);
    for &fmt in &[Format::Csr, Format::Csc, Format::Dia, Format::Coo] {
        let Ok(m) = base.convert(fmt) else { continue };
        let t = m.transpose().unwrap();
        assert_eq!(t.format(), fmt, "{fmt}: transpose must preserve format");
        assert_eq!(t.to_coo(), want, "{fmt}: transpose content");
    }
}

#[test]
fn memory_model_tracks_nnz() {
    let mut rng = Rng::new(5);
    let sparse = gen_matrix(&mut rng, 256, 0.01, MatrixPattern::Uniform);
    let dense = gen_matrix(&mut rng, 256, 0.3, MatrixPattern::Uniform);
    for &fmt in &[Format::Coo, Format::Csr, Format::Dok, Format::Lil] {
        let a = SparseMatrix::Coo(sparse.clone()).convert(fmt).unwrap().nbytes();
        let b = SparseMatrix::Coo(dense.clone()).convert(fmt).unwrap().nbytes();
        assert!(b > a, "{fmt}: denser matrix must cost more bytes");
    }
}
