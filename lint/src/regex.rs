//! Backtracking engine for the regex subset the rule spec is allowed to
//! use (documented in rules.json `syntax` and DESIGN.md §Static-Analysis):
//! literals, escapes, `\b \s \S \w \W \d \D`, `[...]` classes, `(?:...)`
//! and capturing `(...)` groups, alternation `|`, quantifiers `* + ?`, and
//! anchors `^ $`. No `{m,n}`, no lookaround, no backreferences — that
//! restriction is what keeps this engine small enough to audit and keeps
//! the spec portable between the two runners.
//!
//! Compilation: pattern → AST → instruction list (`Char`/`Class`/`Split`/
//! `Jmp`/`Save`/assertions). Matching is depth-first backtracking with
//! greedy quantifiers, which reproduces Python `re` semantics on this
//! subset. Positions are char indices (the engine runs on single lines, so
//! input is short and backtracking depth stays bounded).

#[derive(Debug, Clone)]
enum ClassItem {
    Ch(char),
    Range(char, char),
    Digit,
    Word,
    Space,
}

#[derive(Debug, Clone)]
struct ClassSpec {
    neg: bool,
    items: Vec<ClassItem>,
}

fn is_word(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

impl ClassSpec {
    fn matches(&self, c: char) -> bool {
        let hit = self.items.iter().any(|it| match *it {
            ClassItem::Ch(x) => c == x,
            ClassItem::Range(lo, hi) => c >= lo && c <= hi,
            ClassItem::Digit => c.is_ascii_digit(),
            ClassItem::Word => is_word(c),
            ClassItem::Space => c.is_whitespace(),
        });
        hit != self.neg
    }
}

#[derive(Debug, Clone)]
enum Ast {
    Char(char),
    Any,
    Class(ClassSpec),
    Start,
    End,
    WordB,
    Seq(Vec<Ast>),
    Alt(Vec<Ast>),
    Group(Box<Ast>, Option<usize>),
    Star(Box<Ast>),
    Plus(Box<Ast>),
    Quest(Box<Ast>),
}

#[derive(Debug, Clone)]
enum Inst {
    Char(char),
    Any,
    Class(ClassSpec),
    Start,
    End,
    WordB,
    Split(usize, usize),
    Jmp(usize),
    Save(usize),
    Match,
}

pub struct Regex {
    prog: Vec<Inst>,
    ngroups: usize,
}

/// One match: char-index span plus capture-group spans (index 1..).
pub struct MatchInfo {
    pub start: usize,
    pub end: usize,
    pub text: String,
    pub groups: Vec<Option<String>>,
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser {
    chars: Vec<char>,
    pos: usize,
    ngroups: usize,
}

impl Parser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn peek2(&self) -> Option<char> {
        self.chars.get(self.pos + 1).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn alt(&mut self) -> Result<Ast, String> {
        let mut alts = vec![self.seq()?];
        while self.peek() == Some('|') {
            self.pos += 1;
            alts.push(self.seq()?);
        }
        Ok(if alts.len() == 1 {
            alts.pop().unwrap()
        } else {
            Ast::Alt(alts)
        })
    }

    fn seq(&mut self) -> Result<Ast, String> {
        let mut items = Vec::new();
        while let Some(c) = self.peek() {
            if c == '|' || c == ')' {
                break;
            }
            items.push(self.rep()?);
        }
        Ok(Ast::Seq(items))
    }

    fn rep(&mut self) -> Result<Ast, String> {
        let atom = self.atom()?;
        match self.peek() {
            Some('*') => {
                self.pos += 1;
                Ok(Ast::Star(Box::new(atom)))
            }
            Some('+') => {
                self.pos += 1;
                Ok(Ast::Plus(Box::new(atom)))
            }
            Some('?') => {
                self.pos += 1;
                Ok(Ast::Quest(Box::new(atom)))
            }
            _ => Ok(atom),
        }
    }

    fn atom(&mut self) -> Result<Ast, String> {
        match self.bump() {
            None => Err("unexpected end of pattern".into()),
            Some('(') => {
                let capturing = if self.peek() == Some('?') {
                    if self.peek2() == Some(':') {
                        self.pos += 2;
                        false
                    } else {
                        return Err("only (?:...) groups are supported".into());
                    }
                } else {
                    true
                };
                let idx = if capturing {
                    self.ngroups += 1;
                    Some(self.ngroups)
                } else {
                    None
                };
                let inner = self.alt()?;
                if self.bump() != Some(')') {
                    return Err("unclosed group".into());
                }
                Ok(Ast::Group(Box::new(inner), idx))
            }
            Some('[') => Ok(Ast::Class(self.class()?)),
            Some('.') => Ok(Ast::Any),
            Some('^') => Ok(Ast::Start),
            Some('$') => Ok(Ast::End),
            Some('{') => Err("{m,n} quantifiers are outside the supported subset".into()),
            Some('\\') => {
                let e = self.bump().ok_or("trailing backslash")?;
                Ok(match e {
                    'b' => Ast::WordB,
                    'd' => Ast::Class(ClassSpec { neg: false, items: vec![ClassItem::Digit] }),
                    'D' => Ast::Class(ClassSpec { neg: true, items: vec![ClassItem::Digit] }),
                    'w' => Ast::Class(ClassSpec { neg: false, items: vec![ClassItem::Word] }),
                    'W' => Ast::Class(ClassSpec { neg: true, items: vec![ClassItem::Word] }),
                    's' => Ast::Class(ClassSpec { neg: false, items: vec![ClassItem::Space] }),
                    'S' => Ast::Class(ClassSpec { neg: true, items: vec![ClassItem::Space] }),
                    'n' => Ast::Char('\n'),
                    't' => Ast::Char('\t'),
                    'r' => Ast::Char('\r'),
                    other => Ast::Char(other),
                })
            }
            Some(c) => Ok(Ast::Char(c)),
        }
    }

    fn class_escape(&mut self) -> Result<ClassItem, String> {
        let e = self.bump().ok_or("bad escape in class")?;
        Ok(match e {
            'd' => ClassItem::Digit,
            'w' => ClassItem::Word,
            's' => ClassItem::Space,
            'n' => ClassItem::Ch('\n'),
            't' => ClassItem::Ch('\t'),
            'r' => ClassItem::Ch('\r'),
            other => ClassItem::Ch(other),
        })
    }

    fn class(&mut self) -> Result<ClassSpec, String> {
        let neg = if self.peek() == Some('^') {
            self.pos += 1;
            true
        } else {
            false
        };
        let mut items = Vec::new();
        loop {
            let c = self.bump().ok_or("unterminated character class")?;
            if c == ']' {
                break;
            }
            let item = if c == '\\' { self.class_escape()? } else { ClassItem::Ch(c) };
            if self.peek() == Some('-') && self.peek2().is_some_and(|c2| c2 != ']') {
                self.pos += 1; // consume '-'
                let hi_c = self.bump().unwrap();
                let hi = if hi_c == '\\' {
                    match self.class_escape()? {
                        ClassItem::Ch(h) => h,
                        _ => return Err("class shorthand cannot end a range".into()),
                    }
                } else {
                    hi_c
                };
                match item {
                    ClassItem::Ch(lo) => items.push(ClassItem::Range(lo, hi)),
                    _ => return Err("class shorthand cannot start a range".into()),
                }
            } else {
                items.push(item);
            }
        }
        Ok(ClassSpec { neg, items })
    }
}

// ---------------------------------------------------------------------------
// Compiler
// ---------------------------------------------------------------------------

struct Compiler {
    prog: Vec<Inst>,
}

impl Compiler {
    fn patch_split_b(&mut self, at: usize, to: usize) {
        if let Inst::Split(_, b) = &mut self.prog[at] {
            *b = to;
        }
    }

    fn emit(&mut self, ast: &Ast) {
        match ast {
            Ast::Char(c) => self.prog.push(Inst::Char(*c)),
            Ast::Any => self.prog.push(Inst::Any),
            Ast::Class(cs) => self.prog.push(Inst::Class(cs.clone())),
            Ast::Start => self.prog.push(Inst::Start),
            Ast::End => self.prog.push(Inst::End),
            Ast::WordB => self.prog.push(Inst::WordB),
            Ast::Seq(items) => {
                for it in items {
                    self.emit(it);
                }
            }
            Ast::Alt(alts) => {
                let mut jmps = Vec::new();
                for (i, a) in alts.iter().enumerate() {
                    if i + 1 < alts.len() {
                        let sp = self.prog.len();
                        self.prog.push(Inst::Split(sp + 1, 0));
                        self.emit(a);
                        jmps.push(self.prog.len());
                        self.prog.push(Inst::Jmp(0));
                        let here = self.prog.len();
                        self.patch_split_b(sp, here);
                    } else {
                        self.emit(a);
                    }
                }
                let end = self.prog.len();
                for j in jmps {
                    if let Inst::Jmp(t) = &mut self.prog[j] {
                        *t = end;
                    }
                }
            }
            Ast::Group(inner, idx) => {
                if let Some(i) = idx {
                    self.prog.push(Inst::Save(2 * i));
                    self.emit(inner);
                    self.prog.push(Inst::Save(2 * i + 1));
                } else {
                    self.emit(inner);
                }
            }
            Ast::Star(inner) => {
                let sp = self.prog.len();
                self.prog.push(Inst::Split(sp + 1, 0));
                self.emit(inner);
                self.prog.push(Inst::Jmp(sp));
                let here = self.prog.len();
                self.patch_split_b(sp, here);
            }
            Ast::Plus(inner) => {
                let body = self.prog.len();
                self.emit(inner);
                let sp = self.prog.len();
                self.prog.push(Inst::Split(body, sp + 1));
            }
            Ast::Quest(inner) => {
                let sp = self.prog.len();
                self.prog.push(Inst::Split(sp + 1, 0));
                self.emit(inner);
                let here = self.prog.len();
                self.patch_split_b(sp, here);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

impl Regex {
    pub fn new(pattern: &str) -> Result<Regex, String> {
        let mut p = Parser { chars: pattern.chars().collect(), pos: 0, ngroups: 0 };
        let ast = p.alt()?;
        if p.pos != p.chars.len() {
            return Err(format!("unbalanced pattern near offset {} in {pattern:?}", p.pos));
        }
        let mut c = Compiler { prog: Vec::new() };
        c.prog.push(Inst::Save(0));
        c.emit(&ast);
        c.prog.push(Inst::Save(1));
        c.prog.push(Inst::Match);
        Ok(Regex { prog: c.prog, ngroups: p.ngroups })
    }

    fn step(
        &self,
        pc: usize,
        pos: usize,
        text: &[char],
        saves: &mut Vec<Option<usize>>,
    ) -> Option<usize> {
        match &self.prog[pc] {
            Inst::Match => Some(pos),
            Inst::Char(c) => {
                if text.get(pos) == Some(c) {
                    self.step(pc + 1, pos + 1, text, saves)
                } else {
                    None
                }
            }
            Inst::Any => {
                if pos < text.len() && text[pos] != '\n' {
                    self.step(pc + 1, pos + 1, text, saves)
                } else {
                    None
                }
            }
            Inst::Class(cs) => {
                if pos < text.len() && cs.matches(text[pos]) {
                    self.step(pc + 1, pos + 1, text, saves)
                } else {
                    None
                }
            }
            Inst::Start => {
                if pos == 0 {
                    self.step(pc + 1, pos, text, saves)
                } else {
                    None
                }
            }
            Inst::End => {
                if pos == text.len() {
                    self.step(pc + 1, pos, text, saves)
                } else {
                    None
                }
            }
            Inst::WordB => {
                let before = pos > 0 && is_word(text[pos - 1]);
                let after = pos < text.len() && is_word(text[pos]);
                if before != after {
                    self.step(pc + 1, pos, text, saves)
                } else {
                    None
                }
            }
            Inst::Jmp(t) => self.step(*t, pos, text, saves),
            Inst::Split(a, b) => self
                .step(*a, pos, text, saves)
                .or_else(|| self.step(*b, pos, text, saves)),
            Inst::Save(slot) => {
                let old = saves[*slot];
                saves[*slot] = Some(pos);
                match self.step(pc + 1, pos, text, saves) {
                    Some(end) => Some(end),
                    None => {
                        saves[*slot] = old;
                        None
                    }
                }
            }
        }
    }

    fn match_at(&self, text: &[char], start: usize) -> Option<(usize, Vec<Option<usize>>)> {
        let mut saves: Vec<Option<usize>> = vec![None; 2 * (self.ngroups + 1)];
        self.step(0, start, text, &mut saves)
            .map(|end| (end, saves))
    }

    fn info(text: &[char], start: usize, end: usize, saves: &[Option<usize>], ngroups: usize) -> MatchInfo {
        let slice = |a: usize, b: usize| text[a..b].iter().collect::<String>();
        let mut groups = Vec::with_capacity(ngroups);
        for g in 1..=ngroups {
            let (s, e) = (saves[2 * g], saves[2 * g + 1]);
            groups.push(match (s, e) {
                (Some(s), Some(e)) => Some(slice(s, e)),
                _ => None,
            });
        }
        MatchInfo { start, end, text: slice(start, end), groups }
    }

    /// Leftmost match anywhere in `line` (Python `re.search`).
    pub fn search(&self, line: &str) -> Option<MatchInfo> {
        let text: Vec<char> = line.chars().collect();
        for start in 0..=text.len() {
            if let Some((end, saves)) = self.match_at(&text, start) {
                return Some(Self::info(&text, start, end, &saves, self.ngroups));
            }
        }
        None
    }

    pub fn is_match(&self, line: &str) -> bool {
        self.search(line).is_some()
    }

    /// Non-overlapping leftmost matches (Python `re.finditer`).
    pub fn find_iter(&self, line: &str) -> Vec<MatchInfo> {
        let text: Vec<char> = line.chars().collect();
        let mut out = Vec::new();
        let mut from = 0;
        while from <= text.len() {
            let mut found = None;
            for start in from..=text.len() {
                if let Some((end, saves)) = self.match_at(&text, start) {
                    found = Some(Self::info(&text, start, end, &saves, self.ngroups));
                    break;
                }
            }
            match found {
                None => break,
                Some(m) => {
                    from = if m.end > m.start { m.end } else { m.start + 1 };
                    out.push(m);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_and_alternation() {
        let r = Regex::new(r"\.(?:lock|read|write)\(\)\s*\.(?:unwrap|expect)\(").unwrap();
        assert!(r.is_match("    *m.lock().unwrap()"));
        assert!(r.is_match("l.read() .expect(\"x\")"));
        assert!(!r.is_match("m.lock().unwrap_or_else(recover)"));
    }

    #[test]
    fn word_boundary_and_classes() {
        let r = Regex::new(r"\bunsafe\b").unwrap();
        assert!(r.is_match("unsafe { *p }"));
        assert!(!r.is_match("unsafely"));
        let d = Regex::new(r"Ordering::(?:Relaxed|SeqCst)").unwrap();
        assert!(d.is_match("x.load(Ordering::SeqCst)"));
        assert!(!d.is_match("Ordering::Acquire"));
    }

    #[test]
    fn captures_and_anchors() {
        let r = Regex::new(r"^    ([A-Z][A-Za-z0-9]*)\(").unwrap();
        let m = r.search("    Coo(Coo) = \"COO\",").unwrap();
        assert_eq!(m.groups[0].as_deref(), Some("Coo"));
        assert!(r.search("        Coo(Coo)").is_none());
    }

    #[test]
    fn two_captures() {
        let r = Regex::new(r"lint:\s*allow\(([A-Za-z0-9_,\s-]+)\)\s*--\s*(\S.*)").unwrap();
        let m = r.search("// lint: allow(a-rule, b-rule) -- because reasons").unwrap();
        assert_eq!(m.groups[0].as_deref(), Some("a-rule, b-rule"));
        assert_eq!(m.groups[1].as_deref(), Some("because reasons"));
        assert!(r.search("// lint: allow(a-rule)").is_none());
    }

    #[test]
    fn find_iter_non_overlapping() {
        let r = Regex::new(r"\.clone\(").unwrap();
        assert_eq!(r.find_iter("a.clone(); b.clone()").len(), 2);
    }

    #[test]
    fn fullmatch_globs() {
        let glob = Regex::new(r"^(?:rust/src/(?:.*/)?[^/]*\.rs)$").unwrap();
        assert!(glob.is_match("rust/src/sparse/csr.rs"));
        assert!(glob.is_match("rust/src/lib.rs"));
        assert!(!glob.is_match("rust/tests/model_tests.rs"));
        assert!(!glob.is_match("rust/src/sparse/csr.rs.bak"));
    }

    #[test]
    fn star_backtracks_for_anchor() {
        let r = Regex::new(r"^a.*b$").unwrap();
        assert!(r.is_match("axxbyyb"));
        assert!(!r.is_match("axxbyyc"));
    }

    #[test]
    fn rejects_unsupported_syntax() {
        assert!(Regex::new(r"a{2,3}").is_err());
        assert!(Regex::new(r"(?=x)").is_err());
    }
}
