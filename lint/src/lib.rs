//! In-tree invariant linter — Rust runner (DESIGN.md §Static-Analysis).
//!
//! Interprets the declarative rule spec in `lint/rules.json` against the
//! repo tree. The same spec is interpreted by the stdlib-only Python
//! mirror (`tools/lint.py`), which runs even in containers without a
//! toolchain; the two runners share the fixture corpus under
//! `lint/fixtures/` so they cannot diverge silently.
//!
//! Dependency-free by design: a minimal JSON parser ([`json`]), a
//! backtracking engine for the regex subset the spec is allowed to use
//! ([`regex`]), a comment/string-aware line lexer ([`lexer`]), and the
//! rule interpreter ([`engine`]).

pub mod engine;
pub mod json;
pub mod lexer;
pub mod regex;
