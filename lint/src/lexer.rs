//! Comment/string-aware line lexer, mirroring `tools/lint.py::lex_rust`.
//!
//! Splits source into three column-preserving per-line views:
//!
//! * `code`    — code with string/char-literal *contents* blanked; what
//!               forbid/annotation patterns match against, so a forbidden
//!               token inside an error-message string cannot fire.
//! * `full`    — code with literal contents intact; what exhaustive rules
//!               search, so serialized field names like `"tile"` stay
//!               visible.
//! * `comment` — comment text only; where annotations (`SAFETY:`, `ord:`)
//!               and `// lint:` directives live.
//!
//! Handles line comments, nested block comments, string literals with
//! escapes and `\`-newline continuation, raw strings `r#"..."#` (any hash
//! depth, optional `b` prefix), char literals including escapes, and
//! lifetimes (a lone `'` stays code).

pub struct Lexed {
    pub code: Vec<String>,
    pub full: Vec<String>,
    pub comment: Vec<String>,
}

#[derive(PartialEq)]
enum State {
    Code,
    Line,
    Block,
    Str,
    RawStr,
}

/// Match a char literal at `i` (`chars[i] == '\''`), returning the index
/// one past the closing quote. Mirrors the Python `'(\\[^\n']*|[^\\'\n])'`
/// regex exactly, including its quirk on `'\''` (matches `'\'`, leaving
/// the trailing quote to be lexed as a lifetime).
fn char_lit_end(chars: &[char], i: usize) -> Option<usize> {
    let n = chars.len();
    match chars.get(i + 1) {
        Some('\\') => {
            let mut j = i + 2;
            while j < n && chars[j] != '\n' && chars[j] != '\'' {
                j += 1;
            }
            if j < n && chars[j] == '\'' {
                Some(j + 1)
            } else {
                None
            }
        }
        Some(&c) if c != '\'' && c != '\n' => {
            if i + 2 < n && chars[i + 2] == '\'' {
                Some(i + 3)
            } else {
                None
            }
        }
        _ => None,
    }
}

/// Match a raw-string opener `b?r#*"` at `i`, returning (end index of the
/// opener, hash count). Mirrors the Python `b?r(#*)"` anchored match.
fn raw_str_open(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((j + 1, hashes))
    } else {
        None
    }
}

pub fn lex_rust(text: &str) -> Lexed {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut out = Lexed { code: Vec::new(), full: Vec::new(), comment: Vec::new() };
    let (mut code, mut full, mut com) = (String::new(), String::new(), String::new());
    let mut state = State::Code;
    let mut depth = 0usize;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;

    macro_rules! flush {
        () => {{
            out.code.push(std::mem::take(&mut code));
            out.full.push(std::mem::take(&mut full));
            out.comment.push(std::mem::take(&mut com));
        }};
    }
    macro_rules! emit_code {
        ($s:expr) => {{
            for c in $s.chars() {
                code.push(c);
                full.push(c);
                com.push(' ');
            }
        }};
    }
    macro_rules! emit_com {
        ($s:expr) => {{
            for c in $s.chars() {
                com.push(c);
                code.push(' ');
                full.push(' ');
            }
        }};
    }
    macro_rules! emit_str {
        ($s:expr) => {{
            for c in $s.chars() {
                full.push(c);
                code.push(' ');
                com.push(' ');
            }
        }};
    }

    while i < n {
        let c = chars[i];
        if c == '\n' {
            flush!();
            if state == State::Line {
                state = State::Code;
            }
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let nxt = chars.get(i + 1).copied();
                if c == '/' && nxt == Some('/') {
                    emit_com!("//");
                    state = State::Line;
                    i += 2;
                } else if c == '/' && nxt == Some('*') {
                    emit_com!("/*");
                    state = State::Block;
                    depth = 1;
                    i += 2;
                } else if c == '"' {
                    emit_code!("\"");
                    state = State::Str;
                    i += 1;
                } else if c == 'b' || c == 'r' {
                    if let Some((end, hashes)) = raw_str_open(&chars, i) {
                        let opener: String = chars[i..end].iter().collect();
                        emit_code!(opener);
                        raw_hashes = hashes;
                        state = State::RawStr;
                        i = end;
                    } else {
                        emit_code!(c.to_string());
                        i += 1;
                    }
                } else if c == '\'' {
                    if let Some(end) = char_lit_end(&chars, i) {
                        let body: String = chars[i + 1..end - 1].iter().collect();
                        emit_code!("'");
                        emit_str!(body);
                        emit_code!("'");
                        i = end;
                    } else {
                        // lifetime
                        emit_code!("'");
                        i += 1;
                    }
                } else {
                    emit_code!(c.to_string());
                    i += 1;
                }
            }
            State::Line => {
                emit_com!(c.to_string());
                i += 1;
            }
            State::Block => {
                let nxt = chars.get(i + 1).copied();
                if c == '*' && nxt == Some('/') {
                    emit_com!("*/");
                    depth -= 1;
                    if depth == 0 {
                        state = State::Code;
                    }
                    i += 2;
                } else if c == '/' && nxt == Some('*') {
                    emit_com!("/*");
                    depth += 1;
                    i += 2;
                } else {
                    emit_com!(c.to_string());
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    match chars.get(i + 1).copied() {
                        None | Some('\n') => {
                            emit_str!("\\");
                            i += 1;
                        }
                        Some(nxt) => {
                            emit_str!(format!("\\{nxt}"));
                            i += 2;
                        }
                    }
                } else if c == '"' {
                    emit_code!("\"");
                    state = State::Code;
                    i += 1;
                } else {
                    emit_str!(c.to_string());
                    i += 1;
                }
            }
            State::RawStr => {
                let mut closer = String::from("\"");
                for _ in 0..raw_hashes {
                    closer.push('#');
                }
                let closes = chars[i..].iter().take(closer.chars().count()).collect::<String>() == closer;
                if closes {
                    let len = closer.chars().count();
                    emit_code!(closer);
                    state = State::Code;
                    i += len;
                } else {
                    emit_str!(c.to_string());
                    i += 1;
                }
            }
        }
    }
    flush!();
    if text.ends_with('\n') {
        out.code.pop();
        out.full.pop();
        out.comment.pop();
    }
    out
}

/// Non-.rs files: every line is code (and full); no comment view.
pub fn lex_plain(text: &str) -> Lexed {
    let mut lines: Vec<String> = text.split('\n').map(str::to_string).collect();
    if text.ends_with('\n') {
        lines.pop();
    }
    let comment = vec![String::new(); lines.len()];
    Lexed { code: lines.clone(), full: lines, comment }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blanks_string_contents_in_code_view() {
        let lx = lex_rust("let x = \"Vec::new()\"; // note\n");
        assert!(!lx.code[0].contains("Vec::new"));
        assert!(lx.full[0].contains("Vec::new"));
        assert!(lx.comment[0].contains("note"));
        assert!(!lx.code[0].contains("note"));
        // Column preservation across all three views.
        assert_eq!(lx.code[0].chars().count(), lx.full[0].chars().count());
        assert_eq!(lx.code[0].chars().count(), lx.comment[0].chars().count());
    }

    #[test]
    fn nested_block_comments() {
        let lx = lex_rust("a /* x /* y */ z */ b\n");
        assert!(lx.code[0].contains('a'));
        assert!(lx.code[0].contains('b'));
        assert!(!lx.code[0].contains('y'));
        assert!(lx.comment[0].contains('y'));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let lx = lex_rust("let c = ','; fn f<'a>(x: &'a str) {}\n");
        assert!(!lx.code[0].contains(','));
        assert!(lx.code[0].contains("'a"));
        let src = concat!(r"let q = '\''; // escaped quote", "\n");
        let quirk = lex_rust(src);
        assert!(quirk.comment[0].contains("escaped quote"));
    }

    #[test]
    fn raw_strings() {
        let lx = lex_rust("let s = r#\"has // fake \"comment\"\"#; real();\n");
        assert!(!lx.code[0].contains("fake"));
        assert!(lx.full[0].contains("fake"));
        assert!(lx.code[0].contains("real()"));
        assert!(lx.comment[0].trim().is_empty());
    }

    #[test]
    fn multiline_string_stays_string() {
        let lx = lex_rust("let s = \"line one\nline // two\";\npanic!();\n");
        assert!(!lx.code[1].contains("two"));
        assert!(lx.comment[1].trim().is_empty());
        assert!(lx.code[2].contains("panic!"));
    }

    #[test]
    fn plain_files_have_no_comment_view() {
        let lx = lex_plain("tile: 4 # not rust\n");
        assert_eq!(lx.code[0], "tile: 4 # not rust");
        assert_eq!(lx.full[0], lx.code[0]);
        assert_eq!(lx.comment[0], "");
    }
}
