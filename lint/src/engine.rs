//! Rule interpreter — the Rust port of the engine in `tools/lint.py`.
//!
//! Shared semantics (kept in lock-step with the Python mirror; the fixture
//! corpus under `lint/fixtures/` asserts both runners produce identical
//! (file, line, rule) triples and suppression counts):
//!
//! * `forbid-pattern` — regex over the `code` view, optionally restricted
//!   to `// lint: begin/end(<marker>)` spans, with `except_pattern`
//!   match-span containment; at most one violation per line.
//! * `require-annotation` — every pattern site needs the annotation in the
//!   same-line comment or the contiguous comment block directly above;
//!   `allow_paths` files count sites instead of reporting them.
//! * `exhaustive` — tokens from a literal list or a capture-group regex
//!   over a source region must all appear (via a `{token}`/`{TOKEN}`
//!   template) in every target region, searched in the `full` view.
//! * Directive hygiene — unbalanced markers, malformed allows, unknown
//!   rule names, and allows that suppressed nothing are violations too.

use std::cell::Cell;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::json::Json;
use crate::lexer::{lex_plain, lex_rust, Lexed};
use crate::regex::Regex;

pub const RULE_MARKER_SYNTAX: &str = "lint-marker-syntax";
pub const RULE_ALLOW_SYNTAX: &str = "lint-allow-syntax";
pub const RULE_UNKNOWN_RULE: &str = "lint-unknown-rule";
pub const RULE_UNUSED_ALLOW: &str = "lint-unused-allow";

const SKIP_DIRS: [&str; 4] = [".git", "target", "__pycache__", ".claude"];

/// Translate a path glob to a regex over '/'-separated relative paths.
/// `**/` crosses directories (including zero), `*` and `?` stay within one
/// segment. Identical translation in tools/lint.py.
pub fn glob_to_regex(glob: &str) -> String {
    let chars: Vec<char> = glob.chars().collect();
    let mut out = String::new();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '*' {
            if chars[i..].starts_with(&['*', '*', '/']) {
                out.push_str("(?:.*/)?");
                i += 3;
            } else if chars[i..].starts_with(&['*', '*']) {
                out.push_str(".*");
                i += 2;
            } else {
                out.push_str("[^/]*");
                i += 1;
            }
        } else if c == '?' {
            out.push_str("[^/]");
            i += 1;
        } else if ".^$+(){}[]|\\".contains(c) {
            out.push('\\');
            out.push(c);
            i += 1;
        } else {
            out.push(c);
            i += 1;
        }
    }
    out
}

pub struct Allow {
    pub src_line: usize,
    pub applies_line: usize,
    pub rules: Vec<String>,
    pub reason: String,
    pub used: Cell<bool>,
}

pub struct SourceFile {
    pub rel: String,
    pub code: Vec<String>,
    pub full: Vec<String>,
    pub comment: Vec<String>,
    pub is_rust: bool,
    spans: HashMap<String, Vec<(usize, usize)>>, // marker -> inclusive line ranges
    pub allows: Vec<Allow>,
    pub directive_violations: Vec<(usize, &'static str, String)>,
}

struct DirectiveRes {
    allow: Regex,
    allow_any: Regex,
    begin: Regex,
    end: Regex,
}

impl DirectiveRes {
    fn new() -> DirectiveRes {
        DirectiveRes {
            allow: Regex::new(r"lint:\s*allow\(([A-Za-z0-9_,\s-]+)\)\s*--\s*(\S.*)")
                .expect("built-in allow regex"),
            allow_any: Regex::new(r"lint:\s*allow").expect("built-in allow-any regex"),
            begin: Regex::new(r"lint:\s*begin\(([A-Za-z0-9_-]+)\)").expect("built-in begin regex"),
            end: Regex::new(r"lint:\s*end\(([A-Za-z0-9_-]+)\)").expect("built-in end regex"),
        }
    }
}

impl SourceFile {
    pub fn new(rel: String, lx: Lexed, is_rust: bool) -> SourceFile {
        let mut sf = SourceFile {
            rel,
            code: lx.code,
            full: lx.full,
            comment: lx.comment,
            is_rust,
            spans: HashMap::new(),
            allows: Vec::new(),
            directive_violations: Vec::new(),
        };
        if sf.is_rust {
            sf.scan_directives();
        }
        sf
    }

    fn scan_directives(&mut self) {
        let res = DirectiveRes::new();
        let mut open_spans: BTreeMap<String, usize> = BTreeMap::new();
        for ln in 1..=self.comment.len() {
            let com = self.comment[ln - 1].clone();
            if com.trim().is_empty() {
                continue;
            }
            if let Some(m) = res.begin.search(&com) {
                let name = m.groups[0].clone().unwrap_or_default();
                if open_spans.contains_key(&name) {
                    self.directive_violations.push((
                        ln,
                        RULE_MARKER_SYNTAX,
                        format!("begin({name}) while span already open"),
                    ));
                } else {
                    open_spans.insert(name, ln);
                }
            }
            if let Some(m) = res.end.search(&com) {
                let name = m.groups[0].clone().unwrap_or_default();
                match open_spans.remove(&name) {
                    None => self.directive_violations.push((
                        ln,
                        RULE_MARKER_SYNTAX,
                        format!("end({name}) without begin"),
                    )),
                    Some(start) => {
                        self.spans.entry(name).or_default().push((start, ln));
                    }
                }
            }
            if res.allow_any.is_match(&com) {
                match res.allow.search(&com) {
                    None => self.directive_violations.push((
                        ln,
                        RULE_ALLOW_SYNTAX,
                        "malformed allow: expected `lint: allow(<rule>) -- <reason>`".to_string(),
                    )),
                    Some(m) => {
                        let rules: Vec<String> = m.groups[0]
                            .as_deref()
                            .unwrap_or("")
                            .split(',')
                            .map(str::trim)
                            .filter(|r| !r.is_empty())
                            .map(str::to_string)
                            .collect();
                        let comment_only = self.code[ln - 1].trim().is_empty();
                        let applies = if comment_only { ln + 1 } else { ln };
                        self.allows.push(Allow {
                            src_line: ln,
                            applies_line: applies,
                            rules,
                            reason: m.groups[1].as_deref().unwrap_or("").trim().to_string(),
                            used: Cell::new(false),
                        });
                    }
                }
            }
        }
        for (name, start) in open_spans {
            self.directive_violations.push((
                start,
                RULE_MARKER_SYNTAX,
                format!("begin({name}) never closed"),
            ));
        }
    }

    pub fn in_span(&self, marker: &str, line: usize) -> bool {
        self.spans
            .get(marker)
            .is_some_and(|ranges| ranges.iter().any(|&(s, e)| s <= line && line <= e))
    }

    pub fn try_allow(&self, rule_id: &str, line: usize) -> Option<&Allow> {
        for a in &self.allows {
            if a.applies_line == line && a.rules.iter().any(|r| r == rule_id) {
                a.used.set(true);
                return Some(a);
            }
        }
        None
    }
}

#[derive(Debug, Clone)]
pub struct Violation {
    pub rel: String,
    pub line: usize,
    pub rule: String,
    pub msg: String,
}

impl Violation {
    pub fn key(&self) -> (&str, usize, &str) {
        (&self.rel, self.line, &self.rule)
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.rel, self.line, self.rule, self.msg)
    }
}

pub struct Engine {
    root: PathBuf,
    rules: Vec<Json>,
    known_ids: HashSet<String>,
    pub files: BTreeMap<String, SourceFile>,
    pub violations: Vec<Violation>,
    pub suppressed: BTreeMap<String, Vec<(String, usize, String)>>,
    pub allowlisted: BTreeMap<String, usize>,
}

impl Engine {
    pub fn new(root: &Path, spec: &Json) -> Result<Engine, String> {
        let rules: Vec<Json> = spec
            .get("rules")
            .and_then(Json::as_arr)
            .ok_or("lint: spec has no `rules` array")?
            .to_vec();
        let mut known_ids: HashSet<String> = rules
            .iter()
            .filter_map(|r| r.str_field("id").map(str::to_string))
            .collect();
        for built_in in [RULE_MARKER_SYNTAX, RULE_ALLOW_SYNTAX, RULE_UNKNOWN_RULE, RULE_UNUSED_ALLOW]
        {
            known_ids.insert(built_in.to_string());
        }
        Ok(Engine {
            root: root.to_path_buf(),
            rules,
            known_ids,
            files: BTreeMap::new(),
            violations: Vec::new(),
            suppressed: BTreeMap::new(),
            allowlisted: BTreeMap::new(),
        })
    }

    // -- file loading -------------------------------------------------------

    fn walk(&self) -> Result<Vec<String>, String> {
        let mut out = Vec::new();
        let mut stack = vec![self.root.clone()];
        while let Some(d) = stack.pop() {
            let rd = fs::read_dir(&d).map_err(|e| format!("lint: cannot list {}: {e}", d.display()))?;
            for entry in rd.flatten() {
                let p = entry.path();
                if p.is_dir() {
                    let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
                    if !SKIP_DIRS.contains(&name) {
                        stack.push(p);
                    }
                } else if p.is_file() {
                    let rel = p
                        .strip_prefix(&self.root)
                        .map_err(|e| e.to_string())?
                        .to_string_lossy()
                        .replace('\\', "/");
                    out.push(rel);
                }
            }
        }
        out.sort();
        Ok(out)
    }

    fn load(&mut self, rel: &str) -> Result<(), String> {
        if self.files.contains_key(rel) {
            return Ok(());
        }
        let bytes = fs::read(self.root.join(rel))
            .map_err(|e| format!("lint: cannot read {rel}: {e}"))?;
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let is_rust = rel.ends_with(".rs");
        let lx = if is_rust { lex_rust(&text) } else { lex_plain(&text) };
        self.files.insert(rel.to_string(), SourceFile::new(rel.to_string(), lx, is_rust));
        Ok(())
    }

    fn select(&self, globs: &[String], all_files: &[String]) -> Result<Vec<String>, String> {
        let mut regexes = Vec::new();
        for g in globs {
            regexes.push(Regex::new(&format!("^(?:{})$", glob_to_regex(g)))?);
        }
        Ok(all_files
            .iter()
            .filter(|f| regexes.iter().any(|rx| rx.is_match(f)))
            .cloned()
            .collect())
    }

    // -- main entry ---------------------------------------------------------

    pub fn run(&mut self) -> Result<(), String> {
        let all_files = self.walk()?;
        let rules = self.rules.clone();
        for rule in &rules {
            let kind = rule.str_field("kind").ok_or("lint: rule missing `kind`")?;
            match kind {
                "forbid-pattern" => self.run_forbid(rule, &all_files)?,
                "require-annotation" => self.run_annotation(rule, &all_files)?,
                "exhaustive" => self.run_exhaustive(rule)?,
                other => return Err(format!("lint: unknown rule kind `{other}` in spec")),
            }
        }
        self.finish_directives();
        self.violations.sort_by(|a, b| a.key().cmp(&b.key()));
        Ok(())
    }

    /// Route a hit through the file's allows: suppressed or reported.
    fn emit(
        sf: &SourceFile,
        rule_id: &str,
        line: usize,
        msg: String,
        violations: &mut Vec<Violation>,
        suppressed: &mut BTreeMap<String, Vec<(String, usize, String)>>,
    ) {
        match sf.try_allow(rule_id, line) {
            Some(a) => suppressed
                .entry(rule_id.to_string())
                .or_default()
                .push((sf.rel.clone(), line, a.reason.clone())),
            None => violations.push(Violation {
                rel: sf.rel.clone(),
                line,
                rule: rule_id.to_string(),
                msg,
            }),
        }
    }

    fn run_forbid(&mut self, rule: &Json, all_files: &[String]) -> Result<(), String> {
        let rule_id = rule.str_field("id").ok_or("lint: rule missing `id`")?.to_string();
        let pat = Regex::new(rule.str_field("pattern").ok_or("lint: forbid rule missing `pattern`")?)?;
        let exc = match rule.str_field("except_pattern") {
            Some(p) => Some(Regex::new(p)?),
            None => None,
        };
        let marker = rule.str_field("within_marker").map(str::to_string);
        for rel in self.select(&rule.str_list("paths"), all_files)? {
            self.load(&rel)?;
            let sf = self.files.get(&rel).expect("just loaded");
            for ln in 1..=sf.code.len() {
                if let Some(m) = &marker {
                    if !sf.in_span(m, ln) {
                        continue;
                    }
                }
                let codeline = &sf.code[ln - 1];
                let exc_spans: Vec<(usize, usize)> = match &exc {
                    Some(e) => e.find_iter(codeline).iter().map(|m| (m.start, m.end)).collect(),
                    None => Vec::new(),
                };
                for m in pat.find_iter(codeline) {
                    if exc_spans.iter().any(|&(s2, e2)| s2 <= m.start && m.end <= e2) {
                        continue;
                    }
                    Self::emit(
                        sf,
                        &rule_id,
                        ln,
                        format!("forbidden pattern `{}`", m.text.trim()),
                        &mut self.violations,
                        &mut self.suppressed,
                    );
                    break; // one violation per line
                }
            }
        }
        Ok(())
    }

    fn run_annotation(&mut self, rule: &Json, all_files: &[String]) -> Result<(), String> {
        let rule_id = rule.str_field("id").ok_or("lint: rule missing `id`")?.to_string();
        let pat = Regex::new(rule.str_field("pattern").ok_or("lint: annotation rule missing `pattern`")?)?;
        let annotation = rule
            .str_field("annotation")
            .ok_or("lint: annotation rule missing `annotation`")?
            .to_string();
        let ann = Regex::new(&annotation)?;
        let allow_paths: HashSet<String> = rule.str_list("allow_paths").into_iter().collect();
        for rel in self.select(&rule.str_list("paths"), all_files)? {
            self.load(&rel)?;
            let sf = self.files.get(&rel).expect("just loaded");
            if allow_paths.contains(&rel) {
                let sites: usize = sf.code.iter().map(|c| pat.find_iter(c).len()).sum();
                if sites > 0 {
                    *self.allowlisted.entry(rule_id.clone()).or_insert(0) += sites;
                }
                continue;
            }
            for ln in 1..=sf.code.len() {
                let m = match pat.search(&sf.code[ln - 1]) {
                    Some(m) => m,
                    None => continue,
                };
                if ann.is_match(&sf.comment[ln - 1]) {
                    continue;
                }
                // Walk the contiguous comment block directly above.
                let mut justified = false;
                let mut j = ln - 1;
                while j >= 1
                    && sf.code[j - 1].trim().is_empty()
                    && !sf.comment[j - 1].trim().is_empty()
                {
                    if ann.is_match(&sf.comment[j - 1]) {
                        justified = true;
                        break;
                    }
                    j -= 1;
                }
                if !justified {
                    Self::emit(
                        sf,
                        &rule_id,
                        ln,
                        format!("`{}` without `{}` justification", m.text, annotation),
                        &mut self.violations,
                        &mut self.suppressed,
                    );
                }
            }
        }
        Ok(())
    }

    // -- exhaustive ---------------------------------------------------------

    /// 1-based inclusive line range for a source/target region, or None if
    /// the region_start never matches. Regions and needles match against
    /// the `full` view so serialized field names stay visible.
    fn region(sf: &SourceFile, target: &Json) -> Result<Option<(usize, usize)>, String> {
        let start_re = match target.str_field("region_start") {
            None => return Ok(Some((1, sf.full.len()))),
            Some(s) => s,
        };
        let rx = Regex::new(start_re)?;
        let mut start = None;
        for ln in 1..=sf.full.len() {
            if rx.is_match(&sf.full[ln - 1]) {
                start = Some(ln);
                break;
            }
        }
        let start = match start {
            None => return Ok(None),
            Some(s) => s,
        };
        let mut end = sf.full.len();
        if let Some(end_pat) = target.str_field("region_end") {
            let rx_end = Regex::new(end_pat)?;
            for ln in start..=sf.full.len() {
                if rx_end.is_match(&sf.full[ln - 1]) {
                    end = ln;
                    break;
                }
            }
        }
        Ok(Some((start, end)))
    }

    fn run_exhaustive(&mut self, rule: &Json) -> Result<(), String> {
        let rule_id = rule.str_field("id").ok_or("lint: rule missing `id`")?.to_string();
        let src = rule.get("source").ok_or("lint: exhaustive rule missing `source`")?.clone();
        let tokens: Vec<String> = if src.get("tokens").is_some() {
            src.str_list("tokens")
        } else {
            let path = src
                .str_field("path")
                .ok_or("lint: exhaustive source missing `path`")?
                .to_string();
            self.load(&path)?;
            let sf = self.files.get(&path).expect("just loaded");
            let (start, end) = match Self::region(sf, &src)? {
                None => {
                    self.violations.push(Violation {
                        rel: sf.rel.clone(),
                        line: 1,
                        rule: rule_id,
                        msg: format!(
                            "source region `{}` not found",
                            src.str_field("region_start").unwrap_or("")
                        ),
                    });
                    return Ok(());
                }
                Some(r) => r,
            };
            let tok_re = Regex::new(
                src.str_field("token_pattern")
                    .ok_or("lint: exhaustive source missing `token_pattern`")?,
            )?;
            let mut toks: Vec<String> = Vec::new();
            for ln in start..=end {
                if let Some(m) = tok_re.search(&sf.full[ln - 1]) {
                    if let Some(g) = m.groups.first().and_then(|g| g.clone()) {
                        if !toks.contains(&g) {
                            toks.push(g);
                        }
                    }
                }
            }
            if toks.is_empty() {
                self.violations.push(Violation {
                    rel: sf.rel.clone(),
                    line: start,
                    rule: rule_id,
                    msg: "no source tokens extracted".to_string(),
                });
                return Ok(());
            }
            toks
        };
        let targets = rule
            .get("targets")
            .and_then(Json::as_arr)
            .ok_or("lint: exhaustive rule missing `targets`")?
            .to_vec();
        for target in &targets {
            let path = target
                .str_field("path")
                .ok_or("lint: exhaustive target missing `path`")?
                .to_string();
            let template = target
                .str_field("template")
                .ok_or("lint: exhaustive target missing `template`")?
                .to_string();
            self.load(&path)?;
            let sf = self.files.get(&path).expect("just loaded");
            let (start, end) = match Self::region(sf, target)? {
                None => {
                    self.violations.push(Violation {
                        rel: sf.rel.clone(),
                        line: 1,
                        rule: rule_id.clone(),
                        msg: format!(
                            "target region `{}` not found",
                            target.str_field("region_start").unwrap_or("")
                        ),
                    });
                    continue;
                }
                Some(r) => r,
            };
            for tok in &tokens {
                let needle = template
                    .replace("{token}", tok)
                    .replace("{TOKEN}", &tok.to_uppercase());
                let found = (start..=end).any(|ln| sf.full[ln - 1].contains(&needle));
                if !found {
                    Self::emit(
                        sf,
                        &rule_id,
                        start,
                        format!("`{needle}` missing from target region (drifted from source list)"),
                        &mut self.violations,
                        &mut self.suppressed,
                    );
                }
            }
        }
        Ok(())
    }

    // -- directive hygiene --------------------------------------------------

    fn finish_directives(&mut self) {
        for sf in self.files.values() {
            for (ln, rule_id, msg) in &sf.directive_violations {
                self.violations.push(Violation {
                    rel: sf.rel.clone(),
                    line: *ln,
                    rule: (*rule_id).to_string(),
                    msg: msg.clone(),
                });
            }
            for a in &sf.allows {
                let unknown: Vec<&String> =
                    a.rules.iter().filter(|r| !self.known_ids.contains(*r)).collect();
                for r in &unknown {
                    self.violations.push(Violation {
                        rel: sf.rel.clone(),
                        line: a.src_line,
                        rule: RULE_UNKNOWN_RULE.to_string(),
                        msg: format!("allow names unknown rule `{r}`"),
                    });
                }
                if !a.used.get() && unknown.is_empty() {
                    self.violations.push(Violation {
                        rel: sf.rel.clone(),
                        line: a.src_line,
                        rule: RULE_UNUSED_ALLOW.to_string(),
                        msg: format!(
                            "allow({}) suppressed nothing — stale?",
                            a.rules.join(", ")
                        ),
                    });
                }
            }
        }
    }

    // -- reporting ----------------------------------------------------------

    pub fn n_rules(&self) -> usize {
        self.rules.len()
    }

    pub fn report(&self) {
        for v in &self.violations {
            println!("{v}");
        }
        let n_supp: usize = self.suppressed.values().map(Vec::len).sum();
        let n_allow: usize = self.allowlisted.values().sum();
        println!(
            "lint: {} files, {} rules, {} violations, {} suppressed, {} allowlisted sites",
            self.files.len(),
            self.rules.len(),
            self.violations.len(),
            n_supp,
            n_allow
        );
        for (rule_id, sites) in &self.suppressed {
            for (rel, line, reason) in sites {
                println!("  suppressed {rule_id} at {rel}:{line}: {reason}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Self-test against the fixture corpus
// ---------------------------------------------------------------------------

pub fn self_test(fixtures_dir: &Path) -> Result<bool, String> {
    let read = |name: &str| -> Result<String, String> {
        fs::read_to_string(fixtures_dir.join(name))
            .map_err(|e| format!("lint: cannot read fixtures {name}: {e}"))
    };
    let spec = Json::parse(&read("rules.json")?)?;
    let expected = Json::parse(&read("expected.json")?)?;
    let mut eng = Engine::new(fixtures_dir, &spec)?;
    eng.run()?;

    let mut got: Vec<(String, usize, String)> = eng
        .violations
        .iter()
        .map(|v| (v.rel.clone(), v.line, v.rule.clone()))
        .collect();
    got.sort();
    let mut want: Vec<(String, usize, String)> = Vec::new();
    for e in expected.get("violations").and_then(Json::as_arr).unwrap_or(&[]) {
        want.push((
            e.str_field("file").unwrap_or("").to_string(),
            e.get("line").and_then(Json::as_usize).unwrap_or(0),
            e.str_field("rule").unwrap_or("").to_string(),
        ));
    }
    want.sort();

    let mut ok = true;
    for miss in want.iter().filter(|w| !got.contains(w)) {
        println!("self-test: expected violation did not fire: {miss:?}");
        ok = false;
    }
    for extra in got.iter().filter(|g| !want.contains(g)) {
        println!("self-test: unexpected violation: {extra:?}");
        ok = false;
    }

    let counts = |obj: Option<&Json>| -> BTreeMap<String, usize> {
        let mut out = BTreeMap::new();
        if let Some(Json::Obj(pairs)) = obj {
            for (k, v) in pairs {
                if let Some(n) = v.as_usize() {
                    out.insert(k.clone(), n);
                }
            }
        }
        out
    };
    let got_supp: BTreeMap<String, usize> =
        eng.suppressed.iter().map(|(k, v)| (k.clone(), v.len())).collect();
    if got_supp != counts(expected.get("suppressed")) {
        println!(
            "self-test: suppression counts {got_supp:?} != expected {:?}",
            counts(expected.get("suppressed"))
        );
        ok = false;
    }
    if eng.allowlisted != counts(expected.get("allowlisted")) {
        println!(
            "self-test: allowlisted counts {:?} != expected {:?}",
            eng.allowlisted,
            counts(expected.get("allowlisted"))
        );
        ok = false;
    }
    println!(
        "self-test: {} expected violations, {} suppressions — {}",
        want.len(),
        got_supp.values().sum::<usize>(),
        if ok { "OK" } else { "FAIL" }
    );
    Ok(ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glob_translation() {
        assert_eq!(glob_to_regex("rust/src/sparse/*.rs"), "rust/src/sparse/[^/]*\\.rs");
        assert_eq!(glob_to_regex("rust/src/**/*.rs"), "rust/src/(?:.*/)?[^/]*\\.rs");
        assert_eq!(glob_to_regex("ci.sh"), "ci\\.sh");
    }

    #[test]
    fn allow_parsing_and_span_tracking() {
        let src = "\
// lint: begin(hot)\n\
let a = 1; // lint: allow(some-rule) -- a fine reason\n\
// lint: allow(other-rule) -- covers the next line\n\
let b = 2;\n\
// lint: end(hot)\n";
        let sf = SourceFile::new("x.rs".into(), lex_rust(src), true);
        assert!(sf.directive_violations.is_empty());
        assert!(sf.in_span("hot", 1) && sf.in_span("hot", 5));
        assert!(!sf.in_span("hot", 6));
        assert_eq!(sf.allows.len(), 2);
        assert_eq!(sf.allows[0].applies_line, 2); // trailing: own line
        assert_eq!(sf.allows[1].applies_line, 4); // comment-only: next line
        assert!(sf.try_allow("some-rule", 2).is_some());
        assert!(sf.try_allow("some-rule", 4).is_none());
        assert!(sf.allows[0].used.get());
    }

    #[test]
    fn unbalanced_markers_are_violations() {
        let sf = SourceFile::new(
            "y.rs".into(),
            lex_rust("// lint: begin(a)\n// lint: end(b)\n"),
            true,
        );
        let rules: Vec<&str> = sf.directive_violations.iter().map(|(_, r, _)| *r).collect();
        assert_eq!(rules, vec![RULE_MARKER_SYNTAX, RULE_MARKER_SYNTAX]);
    }
}
