//! CLI for the Rust lint runner. Mirrors `tools/lint.py`:
//!
//!   lint [--root <dir>] [--rules <spec.json>] [--deny] [--self-test]
//!
//! Exit status: 0 clean (or report-only mode), 2 on violations with
//! `--deny` or on a `--self-test` mismatch, 1 on spec/IO errors.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use lint::engine::{self_test, Engine};
use lint::json::Json;

struct Args {
    root: PathBuf,
    rules: Option<PathBuf>,
    deny: bool,
    self_test: bool,
}

fn default_root() -> PathBuf {
    // The crate lives at <repo>/lint, so the repo root is its parent. Fall
    // back to the current directory if the build path no longer exists
    // (e.g. a binary copied to another machine).
    let compiled = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."));
    if compiled.is_dir() {
        compiled
    } else {
        PathBuf::from(".")
    }
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        root: default_root(),
        rules: None,
        deny: false,
        self_test: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => args.root = PathBuf::from(it.next().ok_or("--root needs a value")?),
            "--rules" => args.rules = Some(PathBuf::from(it.next().ok_or("--rules needs a value")?)),
            "--deny" => args.deny = true,
            "--self-test" => args.self_test = true,
            "-h" | "--help" => {
                println!(
                    "usage: lint [--root <dir>] [--rules <spec.json>] [--deny] [--self-test]"
                );
                std::process::exit(0);
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(args)
}

fn run() -> Result<u8, String> {
    let args = parse_args()?;
    if args.self_test {
        let fixtures = args.root.join("lint").join("fixtures");
        return Ok(if self_test(&fixtures)? { 0 } else { 2 });
    }
    let rules_path = args
        .rules
        .unwrap_or_else(|| args.root.join("lint").join("rules.json"));
    let text = std::fs::read_to_string(&rules_path)
        .map_err(|e| format!("lint: cannot read {}: {e}", rules_path.display()))?;
    let spec = Json::parse(&text)?;
    let mut eng = Engine::new(&args.root, &spec)?;
    eng.run()?;
    eng.report();
    Ok(if !eng.violations.is_empty() && args.deny { 2 } else { 0 })
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => ExitCode::from(code),
        Err(msg) => {
            eprintln!("{msg}");
            ExitCode::from(1)
        }
    }
}
