//! Minimal JSON parser — just enough for lint/rules.json and
//! lint/fixtures/expected.json. Objects preserve insertion order (rule
//! evaluation order is spec order, mirroring the Python runner).

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let chars: Vec<char> = text.chars().collect();
        let mut p = Parser { chars, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.chars.len() {
            return Err(format!("trailing data at offset {}", p.pos));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// `get(key)` as a &str, for required string fields.
    pub fn str_field(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    /// `get(key)` as a list of strings (missing key → empty).
    pub fn str_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|v| v.as_str().map(str::to_string)).collect())
            .unwrap_or_default()
    }
}

struct Parser {
    chars: Vec<char>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: char) -> Result<(), String> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(format!("expected `{c}` at offset {}", self.pos))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        for c in word.chars() {
            if self.bump() != Some(c) {
                return Err(format!("bad literal near offset {}", self.pos));
            }
        }
        Ok(value)
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some('{') => self.object(),
            Some('[') => self.array(),
            Some('"') => Ok(Json::Str(self.string()?)),
            Some('t') => self.literal("true", Json::Bool(true)),
            Some('f') => self.literal("false", Json::Bool(false)),
            Some('n') => self.literal("null", Json::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at offset {}", other, self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect('{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some('}') => return Ok(Json::Obj(pairs)),
                other => return Err(format!("expected , or }} got {:?}", other)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect('[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some(']') => return Ok(Json::Arr(items)),
                other => return Err(format!("expected , or ] got {:?}", other)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect('"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some('"') => return Ok(out),
                Some('\\') => match self.bump() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('/') => out.push('/'),
                    Some('b') => out.push('\u{0008}'),
                    Some('f') => out.push('\u{000C}'),
                    Some('n') => out.push('\n'),
                    Some('r') => out.push('\r'),
                    Some('t') => out.push('\t'),
                    Some('u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| c.to_digit(16))
                                .ok_or("bad \\u escape")?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape {:?}", other)),
                },
                Some(c) => out.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some('-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == '.' || c == 'e' || c == 'E' || c == '+' || c == '-')
        {
            self.pos += 1;
        }
        let s: String = self.chars[start..self.pos].iter().collect();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_spec_shapes() {
        let j = Json::parse(r#"{"version": 1, "rules": [{"id": "x", "paths": ["a/*.rs"]}], "ok": true}"#)
            .unwrap();
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(1));
        let rules = j.get("rules").and_then(Json::as_arr).unwrap();
        assert_eq!(rules[0].str_field("id"), Some("x"));
        assert_eq!(rules[0].str_list("paths"), vec!["a/*.rs".to_string()]);
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\\b A \n""#).unwrap();
        assert_eq!(j.as_str(), Some("a\\b A \n"));
    }
}
