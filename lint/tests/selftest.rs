//! Integration tests wiring the Rust runner to the shared fixture corpus
//! and to the real repo tree — the same assertions `tools/lint.py
//! --self-test` and `--deny` make, so the two runners cannot diverge
//! silently.

use std::fs;
use std::path::{Path, PathBuf};

use lint::engine::{self_test, Engine};
use lint::json::Json;

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("lint crate sits under the repo root")
        .to_path_buf()
}

#[test]
fn fixture_corpus_matches_expected() {
    let fixtures = repo_root().join("lint").join("fixtures");
    assert!(
        self_test(&fixtures).expect("fixtures readable"),
        "fixture corpus diverged from expected.json (see stdout)"
    );
}

#[test]
fn real_tree_is_clean() {
    let root = repo_root();
    let spec_text =
        fs::read_to_string(root.join("lint").join("rules.json")).expect("rules.json readable");
    let spec = Json::parse(&spec_text).expect("rules.json parses");
    let mut eng = Engine::new(&root, &spec).expect("spec has rules");
    eng.run().expect("engine runs");
    let rendered: Vec<String> = eng.violations.iter().map(ToString::to_string).collect();
    assert!(
        eng.violations.is_empty(),
        "repo tree has lint violations:\n{}",
        rendered.join("\n")
    );
}

#[test]
fn seeded_violation_is_detected() {
    // Build a tiny throwaway tree with one deliberate violation and check
    // the engine reports exactly that (file, line, rule).
    let dir = std::env::temp_dir().join(format!("lint-seeded-{}", std::process::id()));
    fs::remove_dir_all(&dir).ok();
    let src = dir.join("src");
    fs::create_dir_all(&src).expect("mkdir temp tree");
    fs::write(
        src.join("bad.rs"),
        "pub fn f() {\n    let m = std::sync::Mutex::new(1);\n    let _g = m.lock().unwrap();\n}\n",
    )
    .expect("write seeded source");
    let spec = Json::parse(
        r#"{
          "rules": [
            {
              "id": "lock-discipline",
              "kind": "forbid-pattern",
              "paths": ["src/**/*.rs"],
              "pattern": "\\.(?:lock|read|write)\\(\\)\\s*\\.(?:unwrap|expect)\\("
            }
          ]
        }"#,
    )
    .expect("inline spec parses");
    let mut eng = Engine::new(&dir, &spec).expect("spec has rules");
    eng.run().expect("engine runs");
    fs::remove_dir_all(&dir).ok();
    assert_eq!(eng.violations.len(), 1, "exactly the seeded violation");
    let v = &eng.violations[0];
    assert_eq!((v.rel.as_str(), v.line, v.rule.as_str()), ("src/bad.rs", 3, "lock-discipline"));
}
