//! Directive-hygiene fixture: every malformed or stale directive below is
//! itself a violation.

// lint: begin(hot-path)
pub fn unclosed() {}

// lint: end(request-path)

pub fn malformed() {
    let x = 1; // lint: allow(lock-discipline)
    let y = 2; // lint: allow(no-such-rule) -- fixture: names an unknown rule
    let z = 3; // lint: allow(lock-discipline) -- fixture: suppresses nothing, stale
    drop((x, y, z));
}
