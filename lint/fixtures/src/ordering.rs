//! atomic-ordering-audit fixture: a bare Ordering site fires; `ord:` on the
//! same line or in the block above justifies; a comment-line allow covers
//! the next line.

use std::sync::atomic::{AtomicU64, Ordering};

pub static C: AtomicU64 = AtomicU64::new(0);

pub fn bare() -> u64 {
    C.load(Ordering::Relaxed)
}

pub fn trailing() -> u64 {
    C.load(Ordering::Acquire) // ord: fixture — pairs with a Release store
}

pub fn above() {
    // ord: fixture — justification in the comment block above.
    C.store(1, Ordering::Release);
}

pub fn next_line_allow() -> u64 {
    // lint: allow(atomic-ordering-audit) -- fixture: allow on a comment line covers the next line
    C.load(Ordering::SeqCst)
}
