//! unsafe-audit fixture: bare `unsafe` fires; a SAFETY comment on the same
//! line or in the contiguous comment block directly above justifies it.

pub fn naked(p: *const u8) -> u8 {
    unsafe { *p }
}

pub fn trailing(p: *const u8) -> u8 {
    unsafe { *p } // SAFETY: fixture — caller guarantees p is valid
}

pub fn block_above(p: *const u8) -> u8 {
    // SAFETY: fixture — justified by this comment block
    // spanning two lines directly above the unsafe site.
    unsafe { *p }
}

pub fn suppressed(p: *const u8) -> u8 {
    unsafe { *p } // lint: allow(unsafe-audit) -- fixture: suppression instead of annotation
}
