//! Fixture for durability-io: raw file mutation in a persistence path.

use std::fs::File;
use std::io::Write;

pub fn bad_checkpoint(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut f = File::create(path)?;
    f.write_all(bytes)?;
    Ok(())
}

pub fn bad_save(path: &std::path::Path) -> std::io::Result<()> {
    std::fs::write(path, b"state")
}

pub fn deliberate_corruption(path: &std::path::Path) -> std::io::Result<()> {
    // lint: allow(durability-io) -- fixture: deliberate torn-file write in a test
    std::fs::write(path, b"torn")
}
