//! Exhaustiveness drift fixture, source side: 8 variants (the 8th, Cbm, is
//! deliberately missing from the dispatch match in drift_dispatch.rs).

pub enum Format {
    Coo,
    Csr,
    Csc,
    Dia,
    Bsr,
    Dok,
    Lil,
    Cbm,
}
