//! Exhaustiveness drift fixture, target side: the match arm list drifted
//! (no Cbm), while the length-pinned ALL const kept up.

use super::drift_source::Format;

pub fn name(f: &Format) -> &'static str {
    match f {
        Format::Coo => "coo",
        Format::Csr => "csr",
        Format::Csc => "csc",
        Format::Dia => "dia",
        Format::Bsr => "bsr",
        Format::Dok => "dok",
        Format::Lil => "lil",
        _ => "other",
    }
}

pub const ALL: [&str; 8] = [
    "Format::Coo",
    "Format::Csr",
    "Format::Csc",
    "Format::Dia",
    "Format::Bsr",
    "Format::Dok",
    "Format::Lil",
    "Format::Cbm",
];
