//! lock-discipline fixture: raw lock + unwrap/expect fires; the
//! poison-recovering form and suppressed sites do not.

use std::sync::{Mutex, RwLock};

pub fn bad(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}

pub fn also_bad(l: &RwLock<u32>) -> u32 {
    *l.read().expect("poisoned")
}

pub fn fine(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap_or_else(|p| p.into_inner())
}

pub fn allowed(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap() // lint: allow(lock-discipline) -- fixture: intentional raw lock site
}
