//! Allowlisted-file fixture for atomic-ordering-audit: the three sites
//! below are counted as allowlisted, never flagged.

use std::sync::atomic::{AtomicU64, Ordering};

pub static N: AtomicU64 = AtomicU64::new(0);

pub fn tick() {
    N.fetch_add(1, Ordering::Relaxed);
}

pub fn read() -> u64 {
    N.load(Ordering::Relaxed)
}

pub fn reset() {
    N.store(0, Ordering::SeqCst);
}
