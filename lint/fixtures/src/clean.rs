//! Negative fixture: nothing in this file may fire any rule.
//! Vec::new, .lock().unwrap(), unsafe, Ordering::Relaxed — comments are
//! invisible to pattern rules, and so are string-literal contents.

pub fn clean() -> String {
    let s = "Vec::new() and .lock().unwrap() and unsafe and Ordering::SeqCst";
    let r = r#"panic!("even raw strings may hold Ordering::Relaxed")"#;
    let joined = [s, r].join(" ");
    joined
}
