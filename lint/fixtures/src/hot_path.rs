//! hot-path-alloc fixture: allocation inside a marked span fires; the same
//! token outside a span, inside a string literal, or in a comment does not.

pub fn cold() {
    let v: Vec<f32> = Vec::new(); // outside any span: no violation
    drop(v);
}

// lint: begin(hot-path)
pub fn kernel(out: &mut [f32]) {
    let bad: Vec<f32> = Vec::new();
    let worse: Vec<f32> = out.iter().copied().collect();
    let msg = "Vec::new inside a string literal is fine";
    // Box::new in a comment is fine too.
    let range = 0..out.len();
    let _ok = range.clone();
    let sneaky = vec![0.0f32; 4]; // lint: allow(hot-path-alloc) -- fixture: justified scratch buffer
    drop((bad, worse, msg, sneaky));
}
// lint: end(hot-path)
