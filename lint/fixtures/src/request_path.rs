//! panic-freedom fixture: panicking constructs fire only inside the marked
//! request-path span.

pub fn setup(xs: &[u32]) -> u32 {
    xs[0] + xs.last().copied().unwrap() // outside the span: no violation
}

// lint: begin(request-path)
pub fn handle(xs: &[u32], i: usize) -> u32 {
    let a = xs[i];
    let b = xs.first().copied().unwrap();
    if i > xs.len() {
        panic!("out of range");
    }
    let c = xs.get(1).copied().unwrap_or(0);
    let d = xs.get(2).copied().expect("nonempty"); // lint: allow(panic-freedom) -- fixture: budgeted assert
    a + b + c + d
}
// lint: end(request-path)
