"""AOT lowering: JAX/Pallas graphs → HLO text artifacts + manifest.json.

HLO *text* is the interchange format (NOT `.serialize()`): jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out ../artifacts``

Shapes are fixed at lowering time (PJRT executables are static); the
constants below match the laptop-scale Cora dataset the e2e example uses
(`DatasetSpec::laptop()` in rust/src/graph/datasets.rs).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---- e2e example shapes (Cora laptop scale) --------------------------------
N = 677          # nodes (2708 / 4)
H = 16           # hidden width
C = 7            # classes
# ---- pallas BSR demo shapes -------------------------------------------------
BS = 16          # block edge
NRB = 43         # row blocks  -> padded n = 688
NPAD = NRB * BS
NNZB_CAP = 4096  # padded block capacity
DSP = 32         # dense operand width for the demo kernel


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_artifacts():
    """Return [(name, hlo_text, input_shapes, output_shapes)]."""
    arts = []

    # L2 forward: (S0, b0, W1) -> (H1, Z1)
    lowered = jax.jit(model.gcn_layer_fwd).lower(f32(N, H), f32(1, H), f32(H, C))
    arts.append((
        "gcn_layer_fwd",
        to_hlo_text(lowered),
        [(N, H), (1, H), (H, C)],
        [(N, H), (N, C)],
    ))

    # L2 loss head: (logits, Y_onehot, mask) -> (loss, dlogits)
    lowered = jax.jit(model.gcn_loss_grad).lower(f32(N, C), f32(N, C), f32(N, 1))
    arts.append((
        "gcn_loss_grad",
        to_hlo_text(lowered),
        [(N, C), (N, C), (N, 1)],
        [(1, 1), (N, C)],
    ))

    # L2 backward: (S0, b0, W1, dZ1) -> (dW1, dS0)
    lowered = jax.jit(model.gcn_layer_bwd).lower(
        f32(N, H), f32(1, H), f32(H, C), f32(N, C)
    )
    arts.append((
        "gcn_layer_bwd",
        to_hlo_text(lowered),
        [(N, H), (1, H), (H, C), (N, C)],
        [(H, C), (N, H)],
    ))

    # L1 pallas BSR SpMM demo: (indptr, indices, blocks2d, X) -> (Y,)
    demo = functools.partial(model.bsr_spmm_demo, bs=BS)
    lowered = jax.jit(demo).lower(
        f32(1, NRB + 1), f32(1, NNZB_CAP), f32(NNZB_CAP * BS, BS), f32(NPAD, DSP)
    )
    arts.append((
        "bsr_spmm_demo",
        to_hlo_text(lowered),
        [(1, NRB + 1), (1, NNZB_CAP), (NNZB_CAP * BS, BS), (NPAD, DSP)],
        [(NPAD, DSP)],
    ))

    return arts


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"artifacts": [], "constants": {
        "N": N, "H": H, "C": C, "BS": BS, "NRB": NRB, "NPAD": NPAD,
        "NNZB_CAP": NNZB_CAP, "DSP": DSP,
    }}
    for name, hlo, inputs, outputs in lower_artifacts():
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(hlo)
        manifest["artifacts"].append({
            "name": name,
            "file": fname,
            "inputs": [list(s) for s in inputs],
            "outputs": [list(s) for s in outputs],
        })
        print(f"wrote {path} ({len(hlo)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
