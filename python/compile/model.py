"""L2 — the GCN compute graphs AOT-lowered for the rust coordinator.

Split so the *sparse* products (the paper's contribution — format-managed
SpMM) stay in rust, while the dense layer math, the loss/gradient head, and
the L1 Pallas BSR kernel run through XLA:

  gcn_layer_fwd  : (S0, b0, W1)              -> (H1, Z1)
  gcn_loss_grad  : (logits, Y_onehot, mask)  -> (loss, dlogits)
  gcn_layer_bwd  : (S0, b0, W1, dZ1)         -> (dW1, dS0)
  bsr_spmm_demo  : (indptr_f, indices_f, blocks2d, X) -> (Y,)

All functions return tuples (lowered with ``return_tuple=True``) and take
2-D f32 operands so the rust `PjrtEngine` can drive them uniformly.
"""

import jax
import jax.numpy as jnp

from .kernels.bsr_spmm import bsr_spmm


def gcn_layer_fwd(s0, b0, w1):
    """H1 = ReLU(S0 + b0); Z1 = H1 · W1.  b0 is (1, h) broadcast."""
    h1 = jnp.maximum(s0 + b0, 0.0)
    z1 = h1 @ w1
    return h1, z1


def gcn_loss_grad(logits, y_onehot, mask):
    """Masked mean softmax cross-entropy and its gradient wrt logits.

    mask is (n, 1) with 1.0 on training nodes.
    """
    m = logits.max(axis=-1, keepdims=True)
    shifted = logits - m
    logp = shifted - jnp.log(jnp.exp(shifted).sum(axis=-1, keepdims=True))
    n_masked = jnp.maximum(mask.sum(), 1.0)
    loss = -(logp * y_onehot * mask).sum() / n_masked
    dlogits = (jnp.exp(logp) - y_onehot) * mask / n_masked
    return jnp.reshape(loss, (1, 1)), dlogits


def gcn_layer_bwd(s0, b0, w1, dz1):
    """Backward of `gcn_layer_fwd`: dW1 = H1ᵀ·dZ1, dS0 = ReLU'(S0+b0) ⊙ (dZ1·W1ᵀ)."""
    pre = s0 + b0
    h1 = jnp.maximum(pre, 0.0)
    dw1 = h1.T @ dz1
    ds0 = jnp.where(pre > 0.0, dz1 @ w1.T, 0.0)
    return dw1, ds0


def bsr_spmm_demo(indptr_f, indices_f, blocks2d, x, *, bs):
    """PJRT-friendly wrapper around the L1 Pallas kernel.

    Index arrays arrive as (1, k) f32 matrices (the rust engine speaks f32
    2-D), block storage as (nnzb·bs, bs); cast/reshape here.
    """
    indptr = indptr_f[0].astype(jnp.int32)
    indices = indices_f[0].astype(jnp.int32)
    nnzb = indices.shape[0]
    blocks = blocks2d.reshape(nnzb, bs, bs)
    y = bsr_spmm(indptr, indices, blocks, x, bs=bs)
    return (y,)
