"""L1 — Pallas block-sparse (BSR) SpMM kernel.

TPU adaptation of the paper's format-selection insight (DESIGN.md
§Hardware-Adaptation): of the seven CPU storage formats, the one that maps
onto the MXU systolic array is BSR — dense ``bs × bs`` sub-blocks feed
``jnp.dot`` tiles, and the HBM→VMEM schedule is expressed with a grid over
output row-blocks. Scalar formats (COO/DOK/LIL) have no MXU-efficient
analogue; on TPU the decision collapses to *block-size selection*, ablated
in ``rust/benches/ablation_block_size.rs``.

The kernel MUST run with ``interpret=True``: real TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute. Numerics are
validated against the pure-jnp oracle in ``ref.py``; TPU performance is
estimated from the VMEM footprint / MXU utilization model in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(indptr_ref, indices_ref, blocks_ref, x_ref, o_ref, *, bs, d):
    """One program per output row-block.

    Loops over the row-block's span in ``indices``/``blocks``, gathering the
    matching ``bs × d`` panel of ``x`` and accumulating ``A_blk @ X_blk`` —
    the MXU-shaped inner product. Interpret-mode note: refs are read in full
    and sliced as values; on real TPU the BlockSpec would stream ``blocks``
    through VMEM double-buffered.
    """
    i = pl.program_id(0)
    indptr = indptr_ref[...]
    indices = indices_ref[...]
    blocks = blocks_ref[...]
    x = x_ref[...]
    start = indptr[i]
    end = indptr[i + 1]

    def body(k, acc):
        j = indices[k]
        blk = jax.lax.dynamic_slice(blocks, (k, 0, 0), (1, bs, bs))[0]
        xb = jax.lax.dynamic_slice(x, (j * bs, 0), (bs, d))
        # MXU tile: bs×bs @ bs×d accumulated in f32.
        return acc + jnp.dot(blk, xb, preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(start, end, body, jnp.zeros((bs, d), jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs",))
def bsr_spmm(indptr, indices, blocks, x, *, bs):
    """Block-sparse SpMM: ``A · x`` where ``A`` is BSR.

    Args:
      indptr:  (nrb + 1,) int32 — row-block pointer.
      indices: (nnzb,)   int32 — column-block id per stored block. Padding
               blocks (beyond ``indptr[-1]``) are never visited.
      blocks:  (nnzb, bs, bs) float — dense block storage.
      x:       (ncols_padded, d) float — dense operand, rows padded to a
               multiple of ``bs``.
      bs:      block edge (static).

    Returns:
      (nrb * bs, d) float32 dense result.
    """
    nrb = indptr.shape[0] - 1
    d = x.shape[1]
    kernel = functools.partial(_kernel, bs=bs, d=d)
    return pl.pallas_call(
        kernel,
        grid=(nrb,),
        in_specs=[
            pl.BlockSpec(indptr.shape, lambda i: (0,)),
            pl.BlockSpec(indices.shape, lambda i: (0,)),
            pl.BlockSpec(blocks.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nrb * bs, d), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(indptr, indices, blocks, x)


def dense_to_bsr(a, bs, nnzb_cap=None):
    """Compile-time helper: convert a dense matrix to padded BSR arrays.

    Returns ``(indptr, indices, blocks, n_padded)`` with ``nnzb`` padded to
    ``nnzb_cap`` (zero blocks appended past ``indptr[-1]``, never visited by
    the kernel). Not used at runtime — rust owns the runtime formats.
    """
    import numpy as np

    a = np.asarray(a, dtype=np.float32)
    n, m = a.shape
    nrb = -(-n // bs)
    ncb = -(-m // bs)
    padded = np.zeros((nrb * bs, ncb * bs), dtype=np.float32)
    padded[:n, :m] = a
    indptr = [0]
    indices = []
    blocks = []
    for i in range(nrb):
        for j in range(ncb):
            blk = padded[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs]
            if np.any(blk != 0):
                indices.append(j)
                blocks.append(blk)
        indptr.append(len(indices))
    nnzb = len(indices)
    cap = nnzb_cap or max(nnzb, 1)
    if nnzb > cap:
        raise ValueError(f"nnzb {nnzb} exceeds capacity {cap}")
    indices = np.asarray(indices + [0] * (cap - nnzb), dtype=np.int32)
    blocks = np.asarray(
        blocks + [np.zeros((bs, bs), np.float32)] * (cap - nnzb), dtype=np.float32
    ).reshape(cap, bs, bs)
    return (
        np.asarray(indptr, dtype=np.int32),
        indices,
        blocks,
        nrb * bs,
    )
