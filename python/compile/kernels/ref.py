"""Pure-jnp correctness oracles for the Pallas kernels and the L2 graphs.

These are the ground truth the pytest suite (and `aot.py` self-checks)
compare against — deliberately simple, no pallas, no tricks.
"""

import jax.numpy as jnp


def bsr_to_dense(indptr, indices, blocks, n_rows, n_cols):
    """Reconstruct the dense matrix from (padded) BSR arrays."""
    bs = blocks.shape[1]
    out = jnp.zeros((n_rows, n_cols), jnp.float32)
    nrb = indptr.shape[0] - 1
    for i in range(nrb):
        for k in range(int(indptr[i]), int(indptr[i + 1])):
            j = int(indices[k])
            out = out.at[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs].add(blocks[k])
    return out


def bsr_spmm_ref(indptr, indices, blocks, x):
    """Oracle for `bsr_spmm`: densify then matmul."""
    bs = blocks.shape[1]
    nrb = indptr.shape[0] - 1
    dense = bsr_to_dense(indptr, indices, blocks, nrb * bs, x.shape[0])
    return dense @ x


def _log_softmax(x):
    m = x.max(axis=-1, keepdims=True)
    shifted = x - m
    return shifted - jnp.log(jnp.exp(shifted).sum(axis=-1, keepdims=True))


def gcn_layer_fwd_ref(s0, b0, w1):
    """Oracle for the L2 `gcn_layer_fwd` graph."""
    h1 = jnp.maximum(s0 + b0, 0.0)
    return h1, h1 @ w1


def gcn_loss_grad_ref(logits, y_onehot, mask):
    """Oracle for the L2 masked softmax-xent loss + gradient."""
    logp = _log_softmax(logits)
    n_masked = jnp.maximum(mask.sum(), 1.0)
    loss = -(logp * y_onehot * mask).sum() / n_masked
    probs = jnp.exp(logp)
    dlogits = (probs - y_onehot) * mask / n_masked
    return jnp.reshape(loss, (1, 1)), dlogits


def gcn_layer_bwd_ref(s0, b0, w1, dz1):
    """Oracle for the L2 backward graph: (dw1, ds0)."""
    h1 = jnp.maximum(s0 + b0, 0.0)
    dw1 = h1.T @ dz1
    dh1 = dz1 @ w1.T
    ds0 = jnp.where(s0 + b0 > 0.0, dh1, 0.0)
    return dw1, ds0
