"""L2 correctness: the AOT-lowered GCN graphs vs oracles and jax.grad."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 40), h=st.integers(1, 16), c=st.integers(2, 8),
       seed=st.integers(0, 2**31 - 1))
def test_layer_fwd_matches_ref(n, h, c, seed):
    rng = np.random.default_rng(seed)
    s0, b0, w1 = rand(rng, n, h), rand(rng, 1, h), rand(rng, h, c)
    h1, z1 = model.gcn_layer_fwd(s0, b0, w1)
    h1r, z1r = ref.gcn_layer_fwd_ref(s0, b0, w1)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h1r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z1r), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 40), c=st.integers(2, 8), seed=st.integers(0, 2**31 - 1))
def test_loss_grad_matches_autodiff(n, c, seed):
    rng = np.random.default_rng(seed)
    logits = rand(rng, n, c)
    labels = rng.integers(0, c, n)
    y = np.eye(c, dtype=np.float32)[labels]
    mask = (rng.random((n, 1)) < 0.7).astype(np.float32)
    if mask.sum() == 0:
        mask[0, 0] = 1.0

    loss, dlogits = model.gcn_loss_grad(logits, y, mask)

    def loss_fn(lg):
        l, _ = model.gcn_loss_grad(lg, y, mask)
        return l[0, 0]

    auto = jax.grad(loss_fn)(jnp.asarray(logits))
    np.testing.assert_allclose(np.asarray(dlogits), np.asarray(auto), rtol=1e-4, atol=1e-5)
    # Loss agrees with the oracle.
    lref, _ = ref.gcn_loss_grad_ref(logits, y, mask)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(lref), rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 30), h=st.integers(1, 12), c=st.integers(2, 6),
       seed=st.integers(0, 2**31 - 1))
def test_layer_bwd_matches_autodiff(n, h, c, seed):
    rng = np.random.default_rng(seed)
    s0, b0, w1, dz1 = rand(rng, n, h), rand(rng, 1, h), rand(rng, h, c), rand(rng, n, c)
    dw1, ds0 = model.gcn_layer_bwd(s0, b0, w1, dz1)

    # Autodiff through the forward graph with dz1 as the cotangent.
    def z1_of(s0_, w1_):
        _, z1 = model.gcn_layer_fwd(s0_, b0, w1_)
        return (z1 * dz1).sum()

    auto_ds0 = jax.grad(z1_of, argnums=0)(jnp.asarray(s0), jnp.asarray(w1))
    auto_dw1 = jax.grad(z1_of, argnums=1)(jnp.asarray(s0), jnp.asarray(w1))
    np.testing.assert_allclose(np.asarray(ds0), np.asarray(auto_ds0), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw1), np.asarray(auto_dw1), rtol=1e-4, atol=1e-4)


def test_bsr_demo_wrapper_roundtrip():
    """The PJRT-facing wrapper (f32 index matrices) matches the oracle."""
    from compile.kernels.bsr_spmm import dense_to_bsr

    rng = np.random.default_rng(5)
    a = rng.standard_normal((24, 24)).astype(np.float32)
    a[rng.random((24, 24)) < 0.7] = 0.0
    bs = 8
    indptr, indices, blocks, npad = dense_to_bsr(a, bs=bs, nnzb_cap=16)
    x = rng.standard_normal((npad, 5)).astype(np.float32)

    (y,) = model.bsr_spmm_demo(
        indptr[None, :].astype(np.float32),
        indices[None, :].astype(np.float32),
        blocks.reshape(-1, bs),
        x,
        bs=bs,
    )
    want = ref.bsr_spmm_ref(indptr, indices, blocks, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_aot_lowering_produces_hlo_text():
    """Every artifact lowers to parseable HLO text with ENTRY."""
    from compile import aot

    for name, hlo, inputs, outputs in aot.lower_artifacts():
        assert "ENTRY" in hlo, name
        assert len(inputs) >= 1 and len(outputs) >= 1, name
