"""L1 correctness: the Pallas BSR SpMM kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, block sizes, densities and dtypes — the core
correctness signal for the kernel that ships in the AOT artifacts.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bsr_spmm import bsr_spmm, dense_to_bsr
from compile.kernels import ref


def random_bsr(rng, nrb, ncb, bs, block_density, nnzb_cap=None):
    """Random padded BSR arrays with ~block_density of blocks present."""
    indptr = [0]
    indices = []
    blocks = []
    for _ in range(nrb):
        for j in range(ncb):
            if rng.random() < block_density:
                indices.append(j)
                blocks.append(rng.standard_normal((bs, bs)).astype(np.float32))
        indptr.append(len(indices))
    nnzb = len(indices)
    cap = nnzb_cap or max(nnzb, 1)
    indices = np.asarray(indices + [0] * (cap - nnzb), dtype=np.int32)
    blocks = np.asarray(
        blocks + [np.zeros((bs, bs), np.float32)] * (cap - nnzb), np.float32
    ).reshape(cap, bs, bs)
    return np.asarray(indptr, np.int32), indices, blocks


@settings(max_examples=25, deadline=None)
@given(
    nrb=st.integers(1, 6),
    ncb=st.integers(1, 6),
    bs=st.sampled_from([4, 8, 16]),
    d=st.integers(1, 24),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_bsr_spmm_matches_ref(nrb, ncb, bs, d, density, seed):
    rng = np.random.default_rng(seed)
    indptr, indices, blocks = random_bsr(rng, nrb, ncb, bs, density)
    x = rng.standard_normal((ncb * bs, d)).astype(np.float32)
    got = bsr_spmm(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(blocks),
                   jnp.asarray(x), bs=bs)
    want = ref.bsr_spmm_ref(indptr, indices, blocks, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_empty_matrix_gives_zeros():
    indptr = np.zeros(5, np.int32)  # 4 row-blocks, no stored blocks
    indices = np.zeros(1, np.int32)
    blocks = np.zeros((1, 8, 8), np.float32)
    x = np.ones((16, 3), np.float32)
    y = bsr_spmm(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(blocks),
                 jnp.asarray(x), bs=8)
    assert np.all(np.asarray(y) == 0.0)
    assert y.shape == (32, 3)


def test_identity_blocks_copy_x():
    bs, nrb = 4, 3
    # Block-diagonal identity.
    indptr = np.arange(nrb + 1, dtype=np.int32)
    indices = np.arange(nrb, dtype=np.int32)
    blocks = np.stack([np.eye(bs, dtype=np.float32)] * nrb)
    x = np.random.default_rng(0).standard_normal((nrb * bs, 5)).astype(np.float32)
    y = bsr_spmm(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(blocks),
                 jnp.asarray(x), bs=bs)
    np.testing.assert_allclose(np.asarray(y), x, rtol=1e-6)


def test_padding_blocks_are_ignored():
    rng = np.random.default_rng(7)
    indptr, indices, blocks = random_bsr(rng, 3, 3, 4, 0.5, nnzb_cap=64)
    # Poison the padding region — results must not change.
    real = int(indptr[-1])
    poisoned = blocks.copy()
    poisoned[real:] = 1e6
    x = rng.standard_normal((12, 6)).astype(np.float32)
    a = bsr_spmm(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(blocks),
                 jnp.asarray(x), bs=4)
    b = bsr_spmm(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(poisoned),
                 jnp.asarray(x), bs=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_dense_to_bsr_roundtrip():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((20, 28)).astype(np.float32)
    a[rng.random((20, 28)) < 0.6] = 0.0
    indptr, indices, blocks, npad = dense_to_bsr(a, bs=8, nnzb_cap=32)
    dense = np.asarray(ref.bsr_to_dense(indptr, indices, blocks, npad, 32))
    np.testing.assert_allclose(dense[:20, :28], a)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_dtype_tolerance(dtype):
    rng = np.random.default_rng(11)
    indptr, indices, blocks = random_bsr(rng, 2, 2, 8, 0.8)
    x = rng.standard_normal((16, 4)).astype(dtype)
    y = bsr_spmm(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(blocks),
                 jnp.asarray(x.astype(np.float32)), bs=8)
    want = ref.bsr_spmm_ref(indptr, indices, blocks, x.astype(np.float32))
    tol = 1e-4 if dtype == np.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=tol, atol=tol)
